"""WCRDT training metrics: deterministic windows regardless of fold order
(the paper's technique applied to the training-step stream)."""
import itertools

import jax.numpy as jnp
import numpy as np

from repro.training.metrics import (
    MetricSpec,
    metrics_fold,
    metrics_init,
    metrics_merge,
    metrics_read,
)

SPEC = MetricSpec(num_workers=3, window_len=2, num_slots=8)


def _run(order):
    """Each worker folds its own steps into its own replica; merge in the
    given replica order; read window 0."""
    replicas = []
    for w in range(3):
        st = metrics_init(SPEC)
        for step in range(3):  # steps 0..2 per worker; window 0 = steps 0-1
            st = metrics_fold(
                SPEC, st, w, step,
                loss=jnp.float32(w + step * 0.1),
                n_tokens=jnp.float32(100),
                grad_norm=jnp.float32(w * 10 + step),
            )
        replicas.append(st)
    acc = replicas[order[0]]
    for i in order[1:]:
        acc = metrics_merge(SPEC, acc, replicas[i])
    return metrics_read(SPEC, acc, 0)


def test_metric_windows_deterministic_any_merge_order():
    ref, ok = _run((0, 1, 2))
    assert bool(ok)
    for order in itertools.permutations(range(3)):
        vals, ok2 = _run(order)
        assert bool(ok2)
        for k in ref:
            np.testing.assert_allclose(np.asarray(vals[k]), np.asarray(ref[k]), rtol=1e-6)


def test_window_incomplete_until_all_workers_pass():
    st = metrics_init(SPEC)
    # only worker 0 progresses
    for step in range(4):
        st = metrics_fold(SPEC, st, 0, step, jnp.float32(1), jnp.float32(1), jnp.float32(1))
    _, ok = metrics_read(SPEC, st, 0)
    assert not bool(ok), "window must wait for the global watermark"


def test_metric_values_match_plain_aggregation():
    st = metrics_init(SPEC)
    losses = {(w, s): w * 1.0 + s * 0.25 for w in range(3) for s in range(2)}
    for (w, s), l in losses.items():
        st = metrics_fold(SPEC, st, w, s, jnp.float32(l), jnp.float32(7), jnp.float32(l * 2))
    vals, ok = metrics_read(SPEC, st, 0)
    assert bool(ok)
    np.testing.assert_allclose(
        float(vals["mean_loss"]), sum(losses.values()) / 6, rtol=1e-6
    )
    np.testing.assert_allclose(float(vals["tokens"]), 7 * 6, rtol=1e-6)
    np.testing.assert_allclose(float(vals["grad_norm_max"]), max(losses.values()) * 2, rtol=1e-6)
