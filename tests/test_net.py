"""NetworkFabric tests (runtime/net.py, docs/protocol.md §4).

Three layers:
* unit — delivery timing, loss, partitions, degradation, per-link RNG
  isolation, reliable retransmits, RPC retries, byte metering;
* determinism — same seed ⇒ identical delivery traces and byte-identical
  query outputs across two runs, including under a Scenario combining
  crash + partition with lossy jittered links;
* chaos (``-m chaos``, excluded from tier-1) — the slow loss/partition
  sweeps: convergence-despite-loss against the lossless oracle, bounded
  latency degradation, split-brain partition exactness, and the
  centralized baseline's stall-and-replay contrast.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.runtime import (
    HolonHarness,
    Scenario,
    SimConfig,
    run_flink,
    run_holon,
)
from repro.runtime.net import STORAGE, LinkProfile, NetworkFabric
from repro.runtime.sim import Sim
from repro.streaming import make_q7

# ---------------------------------------------------------------------------
# unit: the fabric against a bare simulator
# ---------------------------------------------------------------------------


def mk(profile=None, **kw) -> tuple[Sim, NetworkFabric]:
    sim = Sim()
    net = NetworkFabric(sim, profile=profile or LinkProfile(latency_ms=5.0), **kw)
    return sim, net


def test_lossless_delivers_at_exact_latency_in_order():
    sim, net = mk()
    got = []
    for i in range(4):
        net.send(0, 1, "hb", 10.0, lambda i=i: got.append((i, sim.now)))
    sim.run(until=100.0)
    assert got == [(0, 5.0), (1, 5.0), (2, 5.0), (3, 5.0)]
    assert net.msgs_of("hb") == 4 and net.bytes_of("hb") == 40.0
    assert net.dropped_of("hb") == 0
    # a lossless fixed-latency fabric makes no RNG draws at all
    assert not net._rngs


def test_full_loss_drops_everything():
    sim, net = mk(LinkProfile(latency_ms=5.0, loss=1.0))
    got = []
    for _ in range(6):
        net.send(0, 1, "sync", 100.0, lambda: got.append(sim.now))
    sim.run(until=100.0)
    assert got == [] and net.dropped_of("sync") == 6
    assert net.bytes_of("sync") == 600.0  # wire bytes are paid on send


def test_partition_blocks_cross_group_only_and_heals():
    sim, net = mk()
    got = []
    net.set_partition((0, 1), (2, 3))
    net.send(0, 1, "hb", 1.0, lambda: got.append("intra"))
    net.send(0, 2, "hb", 1.0, lambda: got.append("cross"))
    net.send(0, STORAGE, "ckpt_put", 1.0, lambda: got.append("storage"))
    net.send(4, 5, "hb", 1.0, lambda: got.append("residual"))  # both unlisted
    net.send(4, 0, "hb", 1.0, lambda: got.append("residual-cross"))
    sim.run(until=50.0)
    assert sorted(got) == ["intra", "residual", "storage"]
    assert net.partitioned()
    net.heal()
    net.send(0, 2, "hb", 1.0, lambda: got.append("healed"))
    sim.run(until=100.0)
    assert "healed" in got and not net.partitioned()


def test_reliable_parks_across_partition_and_flushes_on_heal():
    sim, net = mk()
    got = []
    net.set_partition((0,), (1,))
    net.send_reliable(0, 1, "shuffle", 8.0, lambda: got.append(sim.now))
    sim.run(until=200.0)
    assert got == []
    sim.at(200.0, net.heal)
    sim.run(until=300.0)
    assert got == [205.0]  # fresh latency from heal time


def test_degrade_worsens_touching_links_and_clears():
    sim, net = mk()
    net.degrade([1], loss=1.0)
    dead = []
    net.send(0, 1, "hb", 1.0, lambda: dead.append(1))  # into degraded node
    net.send(1, 2, "hb", 1.0, lambda: dead.append(2))  # out of degraded node
    ok = []
    net.send(0, 2, "hb", 1.0, lambda: ok.append(3))  # untouched link
    sim.run(until=50.0)
    assert dead == [] and ok == [3]
    net.degrade([1])  # no overrides -> clear
    net.send(0, 1, "hb", 1.0, lambda: dead.append(4))
    sim.run(until=100.0)
    assert dead == [4]


def test_degrade_latency_floor_applies_to_per_call_latency():
    """A degraded link's latency must slow even messages that carry their
    own base latency (the baseline's shuffle hops) — otherwise degradation
    would skew the Holon-vs-baseline comparison."""
    sim, net = mk()
    net.degrade([1], latency_ms=500.0)
    got = []
    net.send(0, 1, "shuffle", 1.0, lambda: got.append(sim.now), latency_ms=105.0)
    net.send_reliable(0, 1, "shuffle", 1.0, lambda: got.append(sim.now),
                      latency_ms=105.0, hops=2)
    sim.run(until=5000.0)
    assert got == [500.0, 1000.0]


def test_degrade_jitter_on_fixed_profile_takes_effect():
    sim, net = mk(seed=7)
    net.degrade([1], jitter_ms=20.0)
    ts = []
    for _ in range(8):
        net.send(0, 1, "hb", 1.0, lambda: ts.append(sim.now))
    sim.run(until=1000.0)
    assert len(ts) == 8 and any(t > 5.0 for t in ts)  # jitter actually added
    assert all(5.0 <= t <= 25.0 for t in ts)  # bounded by the uniform window


def test_per_link_rng_streams_are_isolated():
    """Traffic on one link must not perturb another link's draws — the
    per-link seeded streams are what make chaos runs reproducible under
    workload changes."""
    prof = LinkProfile(latency_ms=5.0, jitter="uniform", jitter_ms=10.0)

    def latencies(extra_traffic: bool) -> list[float]:
        sim, net = mk(prof, seed=3)
        ts = []
        for i in range(10):
            if extra_traffic:  # interleave sends on an unrelated link
                net.send(0, 2, "hb", 1.0, lambda: None)
            net.send(0, 1, "hb", 1.0, lambda: ts.append(sim.now))
        sim.run(until=1000.0)
        return ts

    assert latencies(False) == latencies(True)


def test_reliable_retransmit_adds_rto_per_loss_and_meters_retries():
    prof = LinkProfile(latency_ms=5.0, loss=0.5)
    sim, net = mk(prof, seed=11, rto_ms=100.0)
    got = []
    for i in range(20):
        net.send_reliable(0, 1, "shuffle", 10.0, lambda i=i: got.append((i, sim.now)))
    sim.run(until=10_000.0)
    assert len(got) == 20  # reliable: everything eventually delivers
    delays = sorted(t - 5.0 for _, t in got)
    assert delays[0] == 0.0 and delays[-1] >= 100.0  # some paid >= 1 RTO
    st = net.stats["shuffle"]
    assert st.retries > 0 and st.bytes > 200.0  # retransmitted bytes metered


def test_rpc_retries_until_delivered_and_gives_up():
    # storage leg loses every message -> RPC re-issues, then gives up
    sim, net = mk(storage_profile=LinkProfile(latency_ms=50.0, loss=1.0),
                  retry_ms=30.0)
    got = []
    net.rpc(0, STORAGE, "ckpt_put", 100.0, lambda: got.append(sim.now), max_tries=4)
    sim.run(until=10_000.0)
    assert got == []
    st = net.stats["ckpt_put"]
    assert st.msgs == 4 and st.retries == 3 and st.dropped == 4
    # a 50% lossy storage link converges: idempotent puts tolerate re-issues
    sim2, net2 = mk(storage_profile=LinkProfile(latency_ms=50.0, loss=0.5),
                    seed=5, retry_ms=30.0)
    got2 = []
    for _ in range(10):
        net2.rpc(0, STORAGE, "ckpt_put", 100.0, lambda: got2.append(sim2.now))
    sim2.run(until=10_000.0)
    assert len(got2) == 10


def test_link_bytes_ledger():
    sim, net = mk()
    net.send(0, 1, "sync", 100.0, lambda: None)
    net.send(0, 1, "hb", 10.0, lambda: None)
    net.send(1, 0, "sync", 50.0, lambda: None)
    assert net.link_bytes[(0, 1)] == 110.0 and net.link_bytes[(1, 0)] == 50.0
    assert net.total_bytes() == 160.0


# ---------------------------------------------------------------------------
# determinism + convergence through the full runtime
# ---------------------------------------------------------------------------

SMALL = SimConfig(
    num_nodes=3,
    num_partitions=6,
    num_batches=40,
    events_per_batch=512,
    rate_per_partition=10_000.0,
    window_len=500,
    num_slots=32,
    ckpt_interval_ms=300.0,
    sync_interval_ms=50.0,
)


def _records(consumer):
    return {
        k: (np.asarray(r.value).tobytes(), r.emit_time, r.latency)
        for k, r in consumer.records.items()
    }


def _values(consumer):
    return {k: np.asarray(r.value) for k, r in consumer.records.items()}


def test_same_seed_identical_trace_and_outputs_under_chaos():
    """Same seed ⇒ byte-identical query outputs AND an identical delivery
    trace across two runs — with lossy jittered links, a crash + restart,
    and a partition-and-heal all in the same Scenario."""
    cfg = dataclasses.replace(
        SMALL, net_loss=0.05, net_jitter="uniform", net_jitter_ms=3.0,
        net_trace=True,
    )
    scen = (
        Scenario("chaos")
        .crash(600.0, 0)
        .restart(1500.0, 0)
        .partition(900.0, (0, 1), (2,))
        .heal(1600.0)
    )

    def once():
        q = make_q7(cfg.num_partitions, window_len=cfg.window_len,
                    num_slots=cfg.num_slots)
        h = HolonHarness(cfg, q)
        c = h.run(scen, horizon_ms=cfg.horizon_ms + 6000.0)
        return h, c

    h1, c1 = once()
    h2, c2 = once()
    assert h1.net.trace, "fabric must have recorded deliveries"
    assert h1.net.trace == h2.net.trace
    assert _records(c1) == _records(c2)
    assert h1.net.class_stats() == h2.net.class_stats()


def test_small_loss_converges_to_lossless_oracle():
    """Tier-1 fast subset of the chaos sweep: 2% gossip loss must still
    produce byte-identical window values (lost deltas are subsumed by the
    next round — at-least-once *eventual* delivery is all gossip needs)."""
    q = make_q7(SMALL.num_partitions, window_len=SMALL.window_len,
                num_slots=SMALL.num_slots)
    oracle = run_holon(SMALL, q)
    lossy = run_holon(dataclasses.replace(SMALL, net_loss=0.02), q)
    ref, got = _values(oracle), _values(lossy)
    assert set(ref) <= set(got)
    for k in ref:
        np.testing.assert_array_equal(got[k], ref[k], err_msg=str(k))
    dropped = sum(s["dropped"] for s in lossy.net_stats.values())
    assert dropped > 0, "2% loss over a full run must actually drop messages"


def test_lossless_fabric_counters_match_legacy_accounting():
    """The fabric's per-class meters are the single source of truth; the
    legacy consumer counters must still see full-state == shipped when
    delta sync is off, and a strict reduction when it is on."""
    q = make_q7(SMALL.num_partitions, window_len=SMALL.window_len,
                num_slots=SMALL.num_slots)
    full = run_holon(dataclasses.replace(SMALL, delta_sync=False), q)
    assert full.sync_bytes == full.sync_bytes_full
    assert full.net_stats["sync"]["bytes"] == full.sync_bytes
    delta = run_holon(SMALL, q)
    assert delta.sync_bytes < full.sync_bytes
    assert {"hb", "sync", "sync_ack", "ckpt_put"} <= set(delta.net_stats)


# ---------------------------------------------------------------------------
# chaos sweeps (slow; scripts/test.sh chaos)
# ---------------------------------------------------------------------------

CHAOS = SimConfig(
    num_batches=150,
    events_per_batch=512,
    window_len=500,
    num_slots=64,
    sync_interval_ms=50.0,
    ckpt_interval_ms=300.0,
)


@pytest.fixture(scope="module")
def chaos_oracle():
    q = make_q7(CHAOS.num_partitions, window_len=CHAOS.window_len,
                num_slots=CHAOS.num_slots)
    return q, run_holon(CHAOS, q)


@pytest.mark.chaos
@pytest.mark.parametrize("loss", [0.01, 0.10])
def test_chaos_loss_sweep_byte_identical_and_bounded(chaos_oracle, loss):
    q, oracle = chaos_oracle
    lossy = run_holon(dataclasses.replace(CHAOS, net_loss=loss), q)
    ref, got = _values(oracle), _values(lossy)
    assert set(ref) <= set(got)
    for k in ref:
        np.testing.assert_array_equal(got[k], ref[k], err_msg=str(k))
    # graceful degradation: <2x end-to-end latency even at 10% gossip loss
    assert lossy.latency_stats()["avg"] < 2.0 * oracle.latency_stats()["avg"]


@pytest.mark.chaos
def test_chaos_partition_split_brain_is_exact(chaos_oracle):
    """During a 2-way partition each side steals everything (split-brain),
    which is *safe*: folds replay deterministically, merges are idempotent,
    duplicates dedup — post-heal outputs are byte-identical to the oracle."""
    q, oracle = chaos_oracle
    members = CHAOS.initial_membership
    scen = (
        Scenario("split")
        .partition(4000.0, members[:2], members[2:])
        .heal(9000.0)
    )
    c = run_holon(CHAOS, q, scen, horizon_ms=CHAOS.horizon_ms + 10_000.0)
    ref, got = _values(oracle), _values(c)
    assert set(ref) <= set(got)
    for k in ref:
        np.testing.assert_array_equal(got[k], ref[k], err_msg=str(k))
    # both sides kept emitting: the spike is bounded by detection + steal,
    # far below the partition duration
    assert c.latency_stats()["p99"] < 5000.0


@pytest.mark.chaos
def test_chaos_flink_partition_stalls_holon_does_not(chaos_oracle):
    """The centralized baseline detects a JM-separating partition like a
    failure: global stop, then restart + restore + replay after heal —
    while Holon's gossip tier rides it out with a bounded spike."""
    q, _ = chaos_oracle
    members = CHAOS.initial_membership
    t0, t1 = 3000.0, 10_000.0  # longer than flink_hb_timeout_ms
    scen = Scenario("split").partition(t0, members[:2], members[2:]).heal(t1)
    horizon = CHAOS.horizon_ms + 30_000.0
    ch = run_holon(CHAOS, q, scen, horizon_ms=horizon)
    cf = run_flink(CHAOS, q, scen, horizon_ms=horizon)
    cf_base = run_flink(CHAOS, q, horizon_ms=horizon)
    # flink recovers eventually (emits everything) but pays detection +
    # restart + replay; holon's worst window beats flink's by a wide margin
    assert len(cf.records) == len(cf_base.records)
    assert cf.latency_stats()["max"] > 10_000.0
    assert ch.latency_stats()["max"] < 0.3 * cf.latency_stats()["max"]


@pytest.mark.chaos
def test_chaos_jitter_and_reorder_preserve_values(chaos_oracle):
    q, oracle = chaos_oracle
    cfgj = dataclasses.replace(
        CHAOS, net_jitter="lognormal", net_jitter_ms=20.0,
        net_reorder_prob=0.1, net_reorder_ms=40.0,
    )
    c = run_holon(cfgj, q)
    ref, got = _values(oracle), _values(c)
    assert set(ref) <= set(got)
    for k in ref:
        np.testing.assert_array_equal(got[k], ref[k], err_msg=str(k))
