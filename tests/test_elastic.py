"""Elastic reconfiguration (paper §4.3): nodes join/leave mid-run via the same
work-stealing path as failure recovery — no global stop, outputs unchanged."""
import numpy as np

from repro.runtime import FailureScenario, SimConfig, run_holon
from repro.streaming import make_q7

CFG = SimConfig(
    num_nodes=4,
    num_partitions=8,
    num_batches=80,
    events_per_batch=512,
    window_len=500,
    num_slots=32,
)


def _vals(consumer):
    return {k: np.asarray(r.value) for k, r in consumer.records.items()}


def test_scale_out_preserves_outputs():
    """A 4th node joins at t=2s (emulated as fail-at-0/restart-at-2s); the
    deterministic assignment rebalances; deduplicated outputs are identical
    to a static 3-node run."""
    q = make_q7(CFG.num_partitions, window_len=CFG.window_len, num_slots=CFG.num_slots)
    # static 3-node reference: node 3 never alive
    ref = run_holon(CFG, q, FailureScenario(
        name="static3", fail_times_ms=(0.5,), fail_nodes=(3,), restart_times_ms=(-1.0,)
    ))
    # elastic: node 3 joins at 2s
    got = run_holon(CFG, q, FailureScenario(
        name="join", fail_times_ms=(0.5,), fail_nodes=(3,), restart_times_ms=(2000.0,)
    ))
    r, g = _vals(ref), _vals(got)
    assert set(r) <= set(g)
    for k in r:
        np.testing.assert_allclose(g[k], r[k], rtol=1e-5, err_msg=str(k))


def test_scale_in_then_out_continuous_progress():
    """Remove a node, later add it back: windows keep completing throughout
    (no global stall beyond the watermark gap)."""
    q = make_q7(CFG.num_partitions, window_len=CFG.window_len, num_slots=CFG.num_slots)
    scen = FailureScenario(
        name="inout", fail_times_ms=(1500.0,), fail_nodes=(1,),
        restart_times_ms=(3500.0,),
    )
    c = run_holon(CFG, q, scen)
    t, lat = c.latency_series()
    horizon = CFG.horizon_ms
    # windows complete across the whole run, including during the gap
    for lo in range(0, int(horizon) - 1000, 1000):
        m = (t >= lo) & (t < lo + 1000)
        assert m.sum() > 0, f"no windows completed in [{lo},{lo+1000})ms"
