"""Online-monitor tests (src/repro/obs/monitor.py, docs/observability.md §6).

Four layers:

* equivalence — on every tier-1 scenario family (and on traces mutated to
  seed each invariant violation) the monitor's online violation set equals
  the post-hoc auditor's, id for id;
* alert mutations — each health alert id (frontier-stall, straggler,
  slo-burn, sync-burn) is driven to fire from a synthetic record stream:
  the monitor is tested to *alert*, not just to stay quiet;
* A/B identity — a run with the monitor attached is byte-identical (consumer
  records and exported traces) to the same seed without it;
* spill — the TraceBuffer JSONL spool keeps evicted records auditable:
  round-trips are lossless and a spilled chaos run still audits clean.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np
import pytest

from repro.obs.audit import audit, audit_harness
from repro.obs.monitor import AUDIT_IDS, OnlineMonitor, replay
from repro.obs.records import TraceBuffer, TraceEvent, mkargs
from repro.runtime import (
    FailureScenario,
    FlinkHarness,
    HolonHarness,
    Scenario,
    SimConfig,
)
from repro.streaming import make_q7

CFG = SimConfig(
    num_nodes=3, num_partitions=4, num_batches=60, window_len=500,
    sync_interval_ms=50.0, ckpt_interval_ms=300.0, obs=True,
)
MON_CFG = dataclasses.replace(CFG, obs_monitor=True)
HORIZON = CFG.horizon_ms + 10_000.0

CHAOS_CFG = dataclasses.replace(
    MON_CFG, net_loss=0.05, net_jitter="uniform", net_jitter_ms=3.0
)
CHAOS_SCEN = (
    Scenario("crash_and_partition")
    .crash(1500.0, 0)
    .partition(2500.0, (1,), (2,))
    .heal(4000.0)
    .restart(4500.0, 0)
)

SCENARIOS = {
    "baseline": None,
    "concurrent": FailureScenario.concurrent(t=2000.0),
    "subsequent": FailureScenario.subsequent(t=1500.0),
    "crash": FailureScenario.crash(t=2000.0),
    "partition_heal": Scenario("ph").partition(2000.0, (0,), (1, 2)).heal(3500.0),
    "elastic": Scenario("el").scale_out(2000.0, 3).scale_in(4000.0, 3),
}


def _q(cfg=CFG):
    return make_q7(cfg.num_partitions, window_len=cfg.window_len,
                   num_slots=cfg.num_slots)


def _run(cfg=CFG, scenario=None, harness_cls=HolonHarness, horizon=HORIZON):
    h = harness_cls(cfg, _q(cfg))
    h.run(scenario, horizon_ms=horizon)
    return h


def _audit_ids(events, cfg) -> set:
    """The auditor's violation set projected onto the shared id catalog."""
    rep = audit(events, cfg=cfg)
    return {i for i in AUDIT_IDS
            if any(f"[{i}]" in v for v in rep.violations)}


def _monitor_ids(events, cfg) -> set:
    return replay(events, cfg=cfg).violation_ids()


# ---------------------------------------------------------------------------
# equivalence: online violation set == post-hoc auditor's
# ---------------------------------------------------------------------------
class TestEquivalence:
    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_holon_clean_scenarios(self, name):
        h = _run(MON_CFG, SCENARIOS[name])
        assert h.monitor is not None
        # the subscription saw every appended record
        assert h.monitor.fed == h.obs.buf.total > 0
        assert h.monitor.violations() == []
        assert audit_harness(h).ok

    @pytest.mark.parametrize("name", ["baseline", "concurrent", "partition_heal"])
    def test_flink_clean_scenarios(self, name):
        h = _run(MON_CFG, SCENARIOS[name], harness_cls=FlinkHarness)
        assert h.monitor is not None and h.monitor.fed == h.obs.buf.total
        assert h.monitor.violations() == []
        assert audit_harness(h).ok

    def test_replay_equals_live_monitor(self):
        h = _run(CHAOS_CFG, CHAOS_SCEN)
        mon = replay(h.obs.buf.events(), cfg=h.cfg)
        assert mon.violation_ids() == h.monitor.violation_ids()
        assert mon.warning_ids() == h.monitor.warning_ids()


# mutation helpers: seed each violation into a certified trace, then check
# the monitor and the auditor flag the *same* id set
def _clean_events():
    h = _run(scenario=SCENARIOS["concurrent"])
    assert audit_harness(h).ok
    return list(h.obs.buf.events()), h.cfg


def _mutate_duplicate(evs):
    first = next(e for e in evs if e.kind == "emit" and e.status == "accepted")
    return evs + [dataclasses.replace(first, t_ms=first.t_ms + 1.0)]


def _mutate_digest(evs):
    first = next(e for e in evs if e.kind == "emit" and e.status == "accepted")
    return evs + [dataclasses.replace(
        first, t_ms=first.t_ms + 1.0, status="duplicate",
        args=mkargs(digest=12345, latency_ms=0.0),
    )]


def _mutate_frontier(evs):
    applies = [e for e in evs if e.kind == "ckpt.apply"]
    last = max(applies, key=lambda e: (e.t_ms, e.arg("nxt_idx", 0)))
    return evs + [dataclasses.replace(
        last, t_ms=last.t_ms + 1.0, args=mkargs(nxt_idx=0, epoch=0),
    )]


def _mutate_unacked(evs):
    merge = next(e for e in evs
                 if e.kind == "sync.recv" and e.status == "delta_merge"
                 and e.arg("marker"))
    return evs + [dataclasses.replace(merge, t_ms=merge.t_ms + 0.123)]


def _mutate_domination(evs):
    merge = next(e for e in evs
                 if e.kind == "sync.recv" and e.status == "delta_merge")
    return evs + [dataclasses.replace(
        merge, t_ms=merge.t_ms + 0.125, args=mkargs(dominated=0, marker=0),
    )]


MUTATIONS = {
    "exactly-once": _mutate_duplicate,
    "exactly-once-digest": _mutate_digest,
    "frontier-regression": _mutate_frontier,
    "unacked-merge": _mutate_unacked,
    "domination": _mutate_domination,
}


class TestMutationEquivalence:
    def test_clean_trace_agrees_empty(self):
        evs, cfg = _clean_events()
        assert _monitor_ids(evs, cfg) == _audit_ids(evs, cfg) == set()

    @pytest.mark.parametrize("name", sorted(MUTATIONS))
    def test_mutated_trace_agrees(self, name):
        evs, cfg = _clean_events()
        mutated = MUTATIONS[name](evs)
        want = _audit_ids(mutated, cfg)
        assert want, f"{name}: auditor missed the seeded violation"
        assert _monitor_ids(mutated, cfg) == want


# ---------------------------------------------------------------------------
# health alerts: each id is driven to fire from a synthetic stream
# ---------------------------------------------------------------------------
def _emit(t, wid, node=0, latency=1.0):
    return TraceEvent(t_ms=t, kind="emit", node=node, partition=0, window=wid,
                      status="accepted", args=mkargs(digest=wid,
                                                     latency_ms=latency))


class TestHealthAlerts:
    def test_frontier_stall_fires_and_is_episodic(self):
        mon = OnlineMonitor(stall_ms=100.0)
        mon.feed(TraceEvent(t_ms=0.0, kind="exec.batch", node=0, partition=0,
                            args=mkargs(wm=1, queue_ms=0.0)))
        mon.feed(TraceEvent(t_ms=500.0, kind="hb.beacon", node=1))
        assert mon.warning_ids() == {"frontier-stall"}
        # still quiet: one alert per stall episode, not per record
        mon.feed(TraceEvent(t_ms=600.0, kind="hb.beacon", node=1))
        assert sum(1 for a in mon.alerts if a.id == "frontier-stall") == 1
        # progress resets the episode; a fresh stall alerts again
        mon.feed(TraceEvent(t_ms=650.0, kind="exec.batch", node=0, partition=0,
                            args=mkargs(wm=2, queue_ms=0.0)))
        mon.feed(TraceEvent(t_ms=1000.0, kind="hb.beacon", node=1))
        assert sum(1 for a in mon.alerts if a.id == "frontier-stall") == 2
        assert mon.violations() == []

    def test_slo_burn_fires(self):
        mon = OnlineMonitor(slo_ms=10.0, slo_frac=0.5)
        for i in range(40):
            mon.feed(_emit(float(i), i, latency=100.0))
        assert "slo-burn" in mon.warning_ids()
        assert mon.violations() == []

    def test_slo_disabled_by_default(self):
        mon = OnlineMonitor()  # slo_ms=0 disables the vote
        for i in range(40):
            mon.feed(_emit(float(i), i, latency=1e9))
        assert "slo-burn" not in mon.warning_ids()

    def test_sync_burn_fires(self):
        mon = OnlineMonitor(sync_budget=10.0)
        mon.feed(TraceEvent(t_ms=500.0, kind="net.msg", src=0, dst=1,
                            cls="sync", status="ok", nbytes=100_000.0,
                            t_end_ms=501.0))
        # crossing into the next bucket closes the hot one -> alert
        mon.feed(TraceEvent(t_ms=1500.0, kind="net.msg", src=0, dst=1,
                            cls="sync", status="ok", nbytes=1.0,
                            t_end_ms=1501.0))
        assert "sync-burn" in mon.warning_ids()
        assert mon.violations() == []

    def test_straggler_fires(self):
        # node 1's folds gate every emission at node 0: after a full origin
        # window the monitor names node 1 a straggler peer
        mon = OnlineMonitor(num_partitions=1)
        for k in range(1, 71):
            t = float(10 * k)
            mon.feed(TraceEvent(t_ms=t, kind="exec.batch", node=1, partition=0,
                                args=mkargs(wm=k, queue_ms=0.0)))
            mon.feed(TraceEvent(t_ms=t + 1.0, kind="net.msg", src=1, dst=0,
                                cls="sync", status="ok", nbytes=64.0,
                                t_end_ms=t + 2.0))
            mon.feed(TraceEvent(t_ms=t + 2.0, kind="sync.recv", node=0, src=1,
                                status="delta_merge",
                                args=mkargs(dominated=1, marker=0)))
            mon.feed(_emit(t + 3.0, k))
        assert "straggler" in mon.warning_ids()
        assert mon.violations() == []

    def test_alert_cap_counts_overflow(self):
        mon = OnlineMonitor()
        for i in range(2000):
            mon._alert(float(i), "frontier-stall", "warn", "x")
        assert len(mon.alerts) == mon.alerts.maxlen
        assert mon.alerts_dropped == 2000 - mon.alerts.maxlen


# ---------------------------------------------------------------------------
# A/B identity: the monitor never perturbs the run
# ---------------------------------------------------------------------------
class TestMonitorPassivity:
    @pytest.mark.parametrize("harness_cls", [HolonHarness, FlinkHarness])
    def test_monitor_on_off_byte_identical(self, harness_cls):
        off = dataclasses.replace(CHAOS_CFG, obs_monitor=False)
        h_on = _run(CHAOS_CFG, CHAOS_SCEN, harness_cls)
        h_off = _run(off, CHAOS_SCEN, harness_cls)
        assert h_on.monitor is not None and h_off.monitor is None
        assert h_on.obs.export_jsonl() == h_off.obs.export_jsonl()
        c_on, c_off = h_on.consumer, h_off.consumer
        assert sorted(c_on.records) == sorted(c_off.records)
        for k in c_on.records:
            a, b = c_on.records[k], c_off.records[k]
            assert a.emit_time == b.emit_time and a.latency == b.latency
            if a.value is not None:
                assert np.array_equal(np.asarray(a.value), np.asarray(b.value))

    def test_monitor_implies_obs(self):
        cfg = dataclasses.replace(CFG, obs=False, obs_monitor=True)
        h = _run(cfg)
        assert h.obs.buf.total > 0
        assert h.monitor.fed == h.obs.buf.total


# ---------------------------------------------------------------------------
# spill: the bounded ring streams evictions to a JSONL spool
# ---------------------------------------------------------------------------
class TestSpill:
    def test_roundtrip_lossless(self, tmp_path):
        spool = str(tmp_path / "spill.jsonl")
        buf = TraceBuffer(cap=8, spill_path=spool)
        evs = [TraceEvent(t_ms=float(i), kind="x", node=i % 3,
                          args=mkargs(k=i, f=0.5 * i)) for i in range(50)]
        for e in evs:
            buf.append(e)
        buf.flush_spill()
        assert buf.total == 50 and buf.dropped == 0
        assert buf.spilled == 50 - len(buf.events())
        assert buf.all_events() == evs  # spool + ring, original order + args

    def test_from_jsonl_preserves_arg_types(self, tmp_path):
        spool = str(tmp_path / "spill.jsonl")
        buf = TraceBuffer(cap=1, spill_path=spool)
        buf.append(TraceEvent(t_ms=1.0, kind="ckpt.apply", partition=2,
                              args=mkargs(wm=(1, 2, 3), nxt_idx=7)))
        buf.append(TraceEvent(t_ms=2.0, kind="y"))
        buf.flush_spill()
        (back,) = buf.spilled_events()
        assert back.arg("wm") == (1, 2, 3)  # lists restore as tuples
        assert back.arg("nxt_idx") == 7

    def test_spilled_chaos_run_audits_clean(self, tmp_path):
        cfg = dataclasses.replace(
            CHAOS_CFG, obs_trace_cap=256,
            obs_spill_path=str(tmp_path / "trace.jsonl"),
        )
        h = _run(cfg, CHAOS_SCEN)
        buf = h.obs.buf
        buf.flush_spill()
        assert buf.spilled > 0 and buf.dropped == 0
        rep = audit(buf.all_events(), cfg=cfg, dropped=buf.dropped)
        assert rep.ok, rep
