"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(the kernel body executes on CPU; lowering targets TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.crdt_merge import crdt_merge_pallas
from repro.kernels.topk_window import topk_window_pallas
from repro.kernels.window_agg import window_agg_pallas


def _events(rng, B, W, dtype):
    vals = rng.standard_normal(B).astype(dtype) * 10
    slots = rng.integers(0, W, size=B).astype(np.int32)
    mask = rng.random(B) > 0.2
    return jnp.array(vals), jnp.array(slots), jnp.array(mask)


@pytest.mark.parametrize("B,W,block", [(256, 8, 256), (512, 16, 256), (1024, 64, 512)])
@pytest.mark.parametrize("op", ["sum", "count", "max", "min"])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_window_agg_unkeyed(B, W, block, op, dtype):
    rng = np.random.default_rng(B + W + len(op))
    vals, slots, mask = _events(rng, B, W, dtype)
    got = window_agg_pallas(vals, slots, mask, W, op=op, block_b=block, interpret=True)
    want = ref.window_agg_ref(vals, slots, mask, W, op=op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,W,C", [(256, 8, 5), (512, 16, 8)])
@pytest.mark.parametrize("op", ["sum", "count", "max"])
def test_window_agg_keyed(B, W, C, op):
    rng = np.random.default_rng(B * C + len(op))
    vals, slots, mask = _events(rng, B, W, np.float32)
    keys = jnp.array(rng.integers(0, C, size=B).astype(np.int32))
    got = window_agg_pallas(vals, slots, mask, W, op=op, keys=keys, C=C, block_b=256, interpret=True)
    want = ref.window_agg_ref(vals, slots, mask, W, op=op, keys=keys, C=C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("op", ["sum", "max"])
@pytest.mark.parametrize("keyed", [False, True])
def test_window_agg_expanded_multi_emit_stream(op, keyed):
    """Pallas/jnp parity on the overlapping-window multi-emit stream: the
    [B*K] expansion of a hopping assigner (rarely a block multiple — the
    kernel pads with mask=False) folds identically to the reference, and
    both match a per-window recount of the raw events."""
    from repro.core.window import Hopping, expand_events

    rng = np.random.default_rng(42 + len(op) + keyed)
    B, C = 300, 5  # B*K = 600: not a multiple of the 256-lane block
    a = Hopping(40, 20)  # K=2
    Wn = 16
    ts = jnp.array(np.sort(rng.integers(0, 40 * 8, size=B)).astype(np.int32))
    vals = jnp.array((rng.random(B) * 10).astype(np.float32))
    mask = jnp.array(rng.random(B) > 0.2)
    keys = jnp.array(rng.integers(0, C, size=B).astype(np.int32)) if keyed else None

    wid, lane_mask = expand_events(a, ts, mask)
    slots = wid % Wn
    lane_vals = jnp.repeat(vals, a.windows_per_event)
    lane_keys = None if keys is None else jnp.repeat(keys, a.windows_per_event)
    got = window_agg_pallas(lane_vals, slots, lane_mask, Wn, op=op,
                            keys=lane_keys, C=C if keyed else 1,
                            block_b=256, interpret=True)
    want = ref.window_agg_ref(lane_vals, slots, lane_mask, Wn, op=op,
                              keys=lane_keys, C=C if keyed else 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    # cross-check one window against a direct recount of the raw events
    w = 3
    m = np.asarray(mask) & np.asarray(a.contains(w, ts))
    if op == "sum" and not keyed:
        np.testing.assert_allclose(
            np.asarray(want)[w % Wn], np.asarray(vals)[m].sum(), rtol=1e-5
        )


def test_window_agg_pallas_pads_ragged_lane_counts():
    """Any lane count works now (expanded streams): pad lanes are inert."""
    rng = np.random.default_rng(3)
    for B in (1, 200, 257, 777):
        vals, slots, mask = _events(rng, B, 8, np.float32)
        got = window_agg_pallas(vals, slots, mask, 8, op="sum",
                                block_b=256, interpret=True)
        want = ref.window_agg_ref(vals, slots, mask, 8, op="sum")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_window_agg_running_state():
    rng = np.random.default_rng(0)
    W = 8
    vals, slots, mask = _events(rng, 256, W, np.float32)
    from repro.kernels.ops import window_agg

    init = jnp.array(rng.standard_normal(W).astype(np.float32))
    got = window_agg(vals, slots, mask, W, op="sum", init=init, use_pallas=True, interpret=True)
    want = ref.window_agg_ref(vals, slots, mask, W, op="sum", init=init)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


@pytest.mark.parametrize("R,F", [(2, 1024), (7, 2048), (16, 4096)])
@pytest.mark.parametrize("op,dtype", [("max", np.float32), ("min", np.float32), ("max", np.int32), ("or", np.uint8)])
def test_crdt_merge(R, F, op, dtype):
    rng = np.random.default_rng(R * F)
    if op == "or":
        stack = jnp.array(rng.integers(0, 2, size=(R, F)).astype(dtype))
    else:
        stack = jnp.array((rng.standard_normal((R, F)) * 100).astype(dtype))
    got = crdt_merge_pallas(stack, op=op, tile_f=1024, interpret=True)
    want = ref.crdt_merge_ref(stack, op=op)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("W,k,B", [(4, 4, 128), (8, 8, 256), (16, 16, 256)])
def test_topk_window(W, k, B):
    rng = np.random.default_rng(W * k + B)
    sv = np.full((W, k), -np.inf, np.float32)
    si = np.zeros((W, k), np.uint32)
    # partially filled running state, desc-sorted
    for w in range(W):
        n = rng.integers(0, k + 1)
        v = np.sort(rng.random(n).astype(np.float32) * 50)[::-1]
        sv[w, :n] = v
        si[w, :n] = rng.integers(0, 1000, size=n)
    vals = jnp.array((rng.random(B) * 100).astype(np.float32))
    ids = jnp.array(rng.integers(0, 1000, size=B).astype(np.uint32))
    slots = jnp.array(rng.integers(0, W, size=B).astype(np.int32))
    mask = jnp.array(rng.random(B) > 0.3)
    gv, gi = topk_window_pallas(jnp.array(sv), jnp.array(si), vals, ids, slots, mask, interpret=True)
    wv, wi = ref.topk_window_ref(jnp.array(sv), jnp.array(si), vals, ids, slots, mask)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(wv), rtol=1e-6)
    # ids must match wherever vals are finite and unique
    finite = np.isfinite(np.asarray(wv))
    np.testing.assert_array_equal(np.asarray(gi)[finite], np.asarray(wi)[finite])


@pytest.mark.parametrize("R,W,F", [(2, 8, 128), (4, 16, 200), (8, 64, 96)])
@pytest.mark.parametrize("op,dtype", [("max", np.float32), ("min", np.float32), ("max", np.int32), ("or", np.uint8)])
def test_gated_delta_merge(R, W, F, op, dtype):
    """Pallas gated delta-merge vs the reference on random dirty masks."""
    from repro.kernels.ops import gated_delta_merge

    rng = np.random.default_rng(R * W + F + len(op))
    wid = rng.integers(-1, 5, size=(R, W)).astype(np.int32)
    if op == "or":
        leaf = rng.integers(0, 2, size=(R, W, F)).astype(dtype)
    else:
        leaf = (rng.standard_normal((R, W, F)) * 50).astype(dtype)
    # clean slots must carry the deterministic zero-state: zero them
    leaf = np.where((wid < 0)[..., None], np.zeros_like(leaf), leaf)
    got = gated_delta_merge(jnp.array(wid), jnp.array(leaf), op=op,
                            use_pallas=True, interpret=True)
    want = ref.gated_delta_merge_ref(jnp.array(wid), jnp.array(leaf), op=op)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("case", ["all_clean", "all_dirty", "one_dirty_row"])
def test_gated_delta_merge_edges(case):
    """Empty-slot edge cases: slot_wid == -1 everywhere (skip path), every
    slot dirty, and a single dirty replica per slot."""
    from repro.kernels.ops import gated_delta_merge

    rng = np.random.default_rng(7)
    R, W, F = 4, 16, 160
    if case == "all_clean":
        wid = np.full((R, W), -1, np.int32)
        leaf = np.zeros((R, W, F), np.float32)
    elif case == "all_dirty":
        wid = rng.integers(0, 3, size=(R, W)).astype(np.int32)
        leaf = rng.standard_normal((R, W, F)).astype(np.float32)
    else:  # exactly one replica owns each slot, the rest are clean
        wid = np.full((R, W), -1, np.int32)
        owner = rng.integers(0, R, size=W)
        wid[owner, np.arange(W)] = rng.integers(0, 9, size=W)
        leaf = rng.standard_normal((R, W, F)).astype(np.float32)
        leaf = np.where((wid < 0)[..., None], np.zeros_like(leaf), leaf)
    got = gated_delta_merge(jnp.array(wid), jnp.array(leaf), op="max",
                            use_pallas=True, interpret=True)
    want = ref.gated_delta_merge_ref(jnp.array(wid), jnp.array(leaf), op="max")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    if case == "all_clean":
        np.testing.assert_array_equal(np.asarray(got), np.zeros((W, F), np.float32))
    if case == "one_dirty_row":
        # the winner's content passes through untouched
        np.testing.assert_array_equal(
            np.asarray(got), leaf[owner, np.arange(W)]
        )


def test_gated_delta_merge_matches_pairwise_wstate_merge():
    """The stacked gated merge equals the slot-aware pairwise WState join."""
    from repro.core import wcrdt as W_
    from repro.core import wgcounter

    spec = wgcounter(window_len=10, num_slots=16, num_partitions=3)
    states = []
    for p in range(3):
        s = spec.zero()
        ts = jnp.array([p * 7 + 1, p * 7 + 12, p * 7 + 30], jnp.int32)
        s = W_.insert(spec, s, p, ts, jnp.ones(3, bool), batch_idx=0,
                      actor=p, amounts=jnp.ones(3))
        s = W_.increment_watermark(spec, s, p, int(ts.max()))
        states.append(W_.delta_since(spec, s, *W_.zero_baseline(spec)))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
    got = W_.merge_delta_stack(spec, stacked)
    want = states[0]
    for s in states[1:]:
        want = W_.merge(spec, want, s)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ops_dispatch_cpu_fallback():
    """On CPU the public ops use the reference path (dry-run stays pure XLA)."""
    from repro.kernels.ops import crdt_merge, topk_window, window_agg

    rng = np.random.default_rng(1)
    vals, slots, mask = _events(rng, 256, 8, np.float32)
    a = window_agg(vals, slots, mask, 8, op="sum")
    b = ref.window_agg_ref(vals, slots, mask, 8, op="sum")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    st = jnp.array(rng.standard_normal((4, 64)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(crdt_merge(st, op="max")), np.asarray(ref.crdt_merge_ref(st, op="max"))
    )
