"""Hash-sharded keyed WCRDT state (docs/protocol.md §6): routing laws, the
shard-and-merge law against the dense keyed counter, and the sharded q5
dataplane against the sparse oracle — clean, under crash-replay, and under
partition.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import wcrdt as W
from repro.core.window import as_assigner


@pytest.mark.parametrize("C,S", [(10, 4), (1000, 8), (1_000_000, 48), (97, 5), (1, 1)])
def test_keyshards_routing_laws(C, S):
    """The multiplicative permutation is a bijection; (shard_of, local_of)
    round-trips through key_table; range sizes partition the domain."""
    sh = W.KeyShards(C, S)
    keys = jnp.arange(C, dtype=jnp.uint32)
    p = np.asarray(sh.perm(keys))
    assert np.array_equal(np.sort(p), np.arange(C))  # bijection
    own, loc = np.asarray(sh.shard_of(keys)), np.asarray(sh.local_of(keys))
    table = sh.key_table()
    assert table.shape == (S, sh.width)
    np.testing.assert_array_equal(table[own, loc], np.arange(C, dtype=np.uint32))
    assert sum(sh.num_local(s) for s in range(S)) == C
    for s in range(S):
        n = sh.num_local(s)
        assert (table[s, :n] < C).all()
        np.testing.assert_array_equal(table[s, n:], C)  # sentinel padding


def test_shard_and_merge_law():
    """Folding a keyed stream through S sharded [W, C/S] states and scattering
    the reads back through key_table reconstructs the dense [W, C] keyed
    counter exactly — sharding changes layout, never values."""
    C, S, wl, slots = 1000, 4, 100, 8
    assigner = as_assigner(wl, wl)
    sh = W.KeyShards(C, S)
    dense = W.wgcounter(wl, slots, 1, key_shape=(C,), assigner=assigner)
    sharded = W.wgcounter_sharded(wl, slots, 1, sh, assigner=assigner)

    rng = np.random.default_rng(0)
    B, nb = 128, 6
    dstate = dense.zero()
    sstates = [sharded.zero() for _ in range(S)]
    for b in range(nb):
        ts = jnp.sort(jnp.asarray(rng.integers(b * 50, (b + 1) * 50, B), jnp.int32))
        keys = jnp.asarray(rng.zipf(1.3, B) % C, jnp.uint32)
        amounts = jnp.ones((B,), jnp.float32)
        mask = jnp.asarray(rng.random(B) < 0.9)
        dstate = W.insert(dense, dstate, 0, ts, mask, batch_idx=b, actor=0,
                          amounts=amounts, keys=keys.astype(jnp.int32))
        dstate = W.increment_watermark(dense, dstate, 0, int(ts.max()))
        own, loc = sh.shard_of(keys), sh.local_of(keys)
        for s in range(S):
            sstates[s] = W.insert(
                sharded, sstates[s], 0, ts, mask & (own == s), batch_idx=b,
                amounts=amounts, keys=loc,
            )
            sstates[s] = W.increment_watermark(sharded, sstates[s], 0, int(ts.max()))

    table = sh.key_table()
    for wid in range(3):
        dv, dok = W.window_value(dense, dstate, wid)
        recon = np.zeros(C, np.float32)
        for s in range(S):
            sv, sok = W.window_value(sharded, sstates[s], wid)
            assert bool(sok) == bool(dok)
            n = sh.num_local(s)
            recon[table[s, :n]] = np.asarray(sv)[:n]
        np.testing.assert_array_equal(recon, np.asarray(dv))


def _run_child(script: str, sentinel: str, timeout: int = 600):
    src = Path(__file__).resolve().parents[1] / "src"
    env = dict(os.environ, PYTHONPATH=str(src))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert sentinel in r.stdout, (
        f"stdout={r.stdout[-2000:]}\nstderr={r.stderr[-2000:]}"
    )


_CHILD_COMMON = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(S)d"
import jax, numpy as np
import jax.numpy as jnp
from repro.core import wcrdt as W
from repro.core.window import as_assigner
from repro.launch.mesh import make_data_mesh
from repro.launch.stream import build_keyed_pipeline, default_fold_schedule
from repro.streaming.generator import NexmarkConfig, generate_log
from repro.streaming.queries import q5_hot_oracle

S, C, nb, epb, wl = %(S)d, %(C)d, %(nb)d, %(epb)d, 100
shards = W.KeyShards(C, S)
mesh = make_data_mesh(S)
nx = NexmarkConfig(num_partitions=S, num_batches=nb, events_per_batch=epb,
                   num_auctions=C, key_skew=1.1)
log = generate_log(nx)
assigner = as_assigner(wl, wl // 2)
closed = int(assigner.first_dirty_wid(nb * nx.batch_span_ms))
n_win = min(closed, 4); first = max(0, closed - n_win)
table = jnp.asarray(shards.key_table())

def run(sched_np, wm_np, sync_every=4):
    with mesh:
        pipe = build_keyed_pipeline(mesh, shards, window_len=wl, num_slots=16,
                                    sync_every=sync_every, n_windows=n_win,
                                    first_window=first)
        oks, vals, shuf, sync = pipe(log, table, jnp.asarray(sched_np),
                                     jnp.asarray(wm_np))
    return (np.asarray(oks), np.asarray(vals), np.asarray(shuf), np.asarray(sync))

base = default_fold_schedule(S, nb)
oks0, vals0, shuf0, sync0 = run(base, np.ones(nb // 4, bool))
assert oks0.sum() == S * n_win, oks0
for i, w in enumerate(range(first, first + n_win)):
    want = np.asarray(q5_hot_oracle(log, w, assigner, C))
    for d in range(S):
        np.testing.assert_array_equal(vals0[d, i], want)
"""


def test_keyed_dataplane_2dev_oracle_smoke():
    """Tier-1 gate: the sharded q5 dataplane on a 2-device mesh at 1e4 keys
    reads byte-identical to the single-process sparse jnp oracle."""
    script = _CHILD_COMMON % dict(S=2, C=10_000, nb=8, epb=256) + """
assert shuf0.ravel().sum() > 0  # cross-device routing actually happened
print("KEYED_2DEV_OK")
"""
    _run_child(script, "KEYED_2DEV_OK")


def test_keyed_provenance_frontier_2dev():
    """``provenance=True`` adds a fifth output — each owner's per-source
    ingest-timestamp frontier over the keyed lanes it folded — without
    changing the default 4-output signature or any default output: the
    frontier must equal the host-side oracle (max bid ts per (owner,
    source) routed pair), and the provenance build's windows/values must be
    byte-identical to the default build's."""
    script = _CHILD_COMMON % dict(S=2, C=10_000, nb=8, epb=256) + """
from repro.streaming.events import KIND_BID

with mesh:
    pipe_p = build_keyed_pipeline(mesh, shards, window_len=wl, num_slots=16,
                                  sync_every=4, n_windows=n_win,
                                  first_window=first, provenance=True)
    out = pipe_p(log, table, jnp.asarray(base), jnp.ones(nb // 4, bool))
assert len(out) == 5  # default build returned 4 (run() unpacks 4-tuples)
oks4, vals4, shuf4, sync4, prov = (np.asarray(x) for x in out)
np.testing.assert_array_equal(oks4, oks0)
np.testing.assert_array_equal(vals4, vals0)
np.testing.assert_array_equal(shuf4, shuf0)

ts, valid = np.asarray(log.ts), np.asarray(log.valid)
bid = valid & (np.asarray(log.kind) == KIND_BID)
auc = np.asarray(log.auction)
own = np.asarray(shards.shard_of(jnp.asarray(auc.reshape(-1), jnp.uint32)))
own = own.reshape(auc.shape)
want = np.full((S, S), -(2**31), np.int64)
for s in range(S):
    for d in range(S):
        m = bid[s] & (own[s] == d)
        if m.any():
            want[d, s] = ts[s][m].max()
np.testing.assert_array_equal(prov.astype(np.int64), want)
assert (want > 0).all()  # every routed pair actually saw bids
print("KEYED_PROV_OK")
"""
    _run_child(script, "KEYED_PROV_OK")


@pytest.mark.multidevice
def test_keyed_dataplane_8dev_crash_and_partition():
    """8-way sharded q5 under chaos: a crash-replay fold schedule and a
    partitioned-then-healed watermark plane both end byte-identical to the
    clean run (and hence to the oracle); a never-healed partition stalls
    every window rather than emitting a wrong value."""
    script = _CHILD_COMMON % dict(S=8, C=10_000, nb=12, epb=256) + """
# crash at step 8, deterministic replay from batch 5 (re-folds are no-ops
# under the folded frontier)
crash = np.concatenate([np.arange(9), np.arange(5, 9), np.arange(9, 12)])
crash = np.tile(crash.astype(np.int32), (S, 1))
oks1, vals1, _, _ = run(crash, np.ones(crash.shape[1] // 4, bool))
np.testing.assert_array_equal(oks1, oks0)
np.testing.assert_array_equal(vals1, vals0)

# partition rounds 1-2 of 6 (watermark exchange suppressed), then heal
wm = np.ones(6, bool); wm[1:3] = False
oks2, vals2, _, sync2 = run(base, wm, sync_every=2)
np.testing.assert_array_equal(oks2, oks0)
np.testing.assert_array_equal(vals2, vals0)
assert sync2.ravel()[0] == 4 * S * 4.0  # 4 healthy rounds x [S] i32 map

# never healed: progress maps stay diverged, every window stalls (not-ok)
oks3, _, _, _ = run(base, np.zeros(6, bool), sync_every=2)
assert oks3.sum() == 0.0, oks3
print("KEYED_8DEV_CHAOS_OK")
"""
    _run_child(script, "KEYED_8DEV_CHAOS_OK")
