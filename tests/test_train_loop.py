"""Training-loop substrate: optimizer correctness, checkpoint/restore
exactly-once semantics (bit-exact continuation after a crash)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.training import adamw_init, adamw_update
from repro.training.checkpoint import LocalStore, TrainCheckpoint


def test_adamw_matches_reference_update():
    """One AdamW step against a hand-computed reference."""
    p = {"w": jnp.array([1.0, -2.0], jnp.float32)}
    g = {"w": jnp.array([0.5, 0.1], jnp.float32)}
    st = adamw_init(p)
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.95, 1e-8, 0.0
    new_p, new_st, gnorm = adamw_update(
        p, g, st, lr=lr, b1=b1, b2=b2, eps=eps, weight_decay=wd, grad_clip=1e9
    )
    m = (1 - b1) * np.array([0.5, 0.1])
    v = (1 - b2) * np.array([0.25, 0.01])
    mhat = m / (1 - b1)
    vhat = v / (1 - b2)
    ref = np.array([1.0, -2.0]) - lr * mhat / (np.sqrt(vhat) + eps)
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref, rtol=1e-6)
    np.testing.assert_allclose(float(gnorm), np.sqrt(0.25 + 0.01), rtol=1e-5)
    assert int(new_st.step) == 1


def test_grad_clip_scales_update():
    p = {"w": jnp.array([0.0], jnp.float32)}
    g = {"w": jnp.array([100.0], jnp.float32)}
    st = adamw_init(p)
    _, _, gnorm = adamw_update(p, g, st, grad_clip=1.0)
    assert float(gnorm) > 99.0  # reported norm is pre-clip


def test_checkpoint_restore_bit_exact(tmp_path):
    """Crash after step k, restore, re-run: identical final params (the
    deterministic-replay property Algorithm 2 relies on)."""
    from repro.launch.train import PRESETS, synthetic_batch
    from repro.models import init_params
    from repro.training.train_step import make_train_step

    cfg = PRESETS["tiny"]
    step_fn = jax.jit(make_train_step(cfg, q_chunk=64, ssm_chunk=32))
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt = adamw_init(params)

    # uninterrupted run of 6 steps
    p1, o1 = params, opt
    for s in range(6):
        p1, o1, _ = step_fn(p1, o1, synthetic_batch(0, s, 2, 64, cfg.vocab))

    # run with a crash after step 3 + restore from checkpoint at step 3
    store = LocalStore(tmp_path)
    p2, o2 = params, opt
    for s in range(3):
        p2, o2, _ = step_fn(p2, o2, synthetic_batch(0, s, 2, 64, cfg.vocab))
    store.put("w0", TrainCheckpoint(step=3, data_idx=3, params=p2, opt=o2, metrics={}, rng_seed=0))
    del p2, o2  # crash: lose volatile state
    ck = store.get("w0")
    p3, o3 = ck.params, ck.opt
    for s in range(ck.step, 6):
        p3, o3, _ = step_fn(p3, o3, synthetic_batch(0, s, 2, 64, cfg.vocab))

    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_store_keeps_largest_step(tmp_path):
    store = LocalStore(tmp_path)
    mk = lambda s: TrainCheckpoint(step=s, data_idx=s, params={"w": jnp.zeros(1)},
                                   opt={}, metrics={}, rng_seed=0)
    assert store.put("k", mk(5))
    assert not store.put("k", mk(3))  # stale write refused (lattice rule)
    assert store.get_step("k") == 5
    assert store.put("k", mk(9))
    assert store.get_step("k") == 9
