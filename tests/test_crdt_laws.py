"""Property tests: every CRDT is a join-semilattice.

merge must be commutative, associative, and idempotent for arbitrary update
interleavings — the foundation of the paper's convergence guarantee (§4.2).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core import (
    GCounter,
    GSet,
    LWWReg,
    MaxReg,
    MinReg,
    PNCounter,
    TopK,
    join,
    join_many,
)

settings.register_profile("ci-laws", max_examples=40, deadline=None)
settings.load_profile("ci-laws")

N_ACTORS = 4


def leaves_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)


# ---- state generators ----


def gcounter_from(ops):
    s = GCounter.zero(N_ACTORS)
    for actor, amt in ops:
        s = s.add(actor % N_ACTORS, abs(amt))
    return s


def pncounter_from(ops):
    s = PNCounter.zero(N_ACTORS)
    for actor, amt in ops:
        s = s.add(actor % N_ACTORS, amt)
    return s


def maxreg_from(ops):
    s = MaxReg.zero(())
    for _, amt in ops:
        s = s.insert(jnp.float32(amt))
    return s


def minreg_from(ops):
    s = MinReg.zero(())
    for _, amt in ops:
        s = s.insert(jnp.float32(amt))
    return s


def gset_from(ops):
    s = GSet.zero(16)
    for actor, amt in ops:
        s = s.insert((actor + int(abs(amt))) % 16)
    return s


def lww_from(ops):
    s = LWWReg.zero(())
    for i, (actor, amt) in enumerate(ops):
        s = s.set_float(i * 7 + actor, amt)
    return s


def topk_from(ops):
    s = TopK.zero(4)
    for actor, amt in ops:
        s = s.insert_batch(
            jnp.array([amt], jnp.float32),
            jnp.array([actor], jnp.uint32),
            jnp.ones(1, bool),
        )
    return s


MAKERS = [gcounter_from, pncounter_from, maxreg_from, minreg_from, gset_from, lww_from, topk_from]

ops_strategy = st.lists(
    st.tuples(st.integers(0, 3), st.floats(-100, 100, allow_nan=False, width=32)),
    min_size=1,
    max_size=8,
)


@pytest.mark.parametrize("maker", MAKERS, ids=[m.__name__ for m in MAKERS])
@given(ops_a=ops_strategy, ops_b=ops_strategy, ops_c=ops_strategy)
def test_lattice_laws(maker, ops_a, ops_b, ops_c):
    a, b, c = maker(ops_a), maker(ops_b), maker(ops_c)
    # commutativity
    leaves_equal(join(a, b), join(b, a))
    # associativity
    leaves_equal(join(join(a, b), c), join(a, join(b, c)))
    # idempotence
    leaves_equal(join(a, a), a)
    ab = join(a, b)
    leaves_equal(join(ab, b), ab)


@pytest.mark.parametrize("maker", MAKERS, ids=[m.__name__ for m in MAKERS])
@given(ops=st.lists(ops_strategy, min_size=2, max_size=5), seed=st.integers(0, 2**16))
def test_convergence_any_order(maker, ops, seed):
    """N replicas merged in any order converge to the same state."""
    states = [maker(o) for o in ops]
    ref = join_many(states)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(states))
    shuffled = [states[i] for i in perm]
    # sequential left fold in shuffled order
    acc = shuffled[0]
    for s in shuffled[1:]:
        acc = join(acc, s)
    leaves_equal(acc, ref)


def test_gcounter_value():
    a = GCounter.zero(3).add(0, 5.0).add(1, 2.0)
    b = GCounter.zero(3).add(1, 2.0).add(2, 4.0)
    # slot 1 written by actor 1 in both with same total update history on b
    m = join(a, b)
    assert float(m.value) == 5.0 + 2.0 + 4.0


def test_pncounter_signed():
    a = PNCounter.zero(2).add(0, 5.0).add(0, -3.0)
    assert float(a.value) == 2.0


def test_topk_set_semantics():
    t = TopK.zero(3)
    t = t.insert_batch(jnp.array([5.0, 5.0]), jnp.array([7, 7], jnp.uint32), jnp.ones(2, bool))
    m = join(t, t)
    vals = np.asarray(m.vals)
    # duplicate (5.0, id 7) collapses to one entry
    assert (vals == 5.0).sum() == 1
