"""Observability-layer tests (src/repro/obs, docs/observability.md).

Four layers:

* unit — trace ring bounding + dropped accounting, registry determinism,
  histogram bucket math, the two exporters' formats (JSONL header/ordering,
  Chrome trace-event schema), clock-domain timers;
* determinism — same-seed runs export byte-identical JSONL/Chrome traces
  (both runtimes, including a crash + partition chaos scenario on lossy
  jittered links), and telemetry on vs off leaves the run's outputs
  untouched;
* auditor-pass — the auditor certifies every tier-1 scenario family
  (baseline, concurrent/subsequent/crash failures, partition + heal,
  elastic scale out/in) on both runtimes;
* auditor-mutation — seeded violations (duplicate emission, checkpoint
  frontier regression, un-acked merge, non-dominated merge, truncated
  ring) are each flagged with the right violation id: the auditor is
  tested to *fail*, not just to pass.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np
import pytest

from repro.obs.audit import audit, audit_harness
from repro.obs.records import TraceBuffer, TraceEvent, mkargs, to_chrome, to_jsonl
from repro.obs.registry import MetricsRegistry, summary
from repro.obs.telemetry import Telemetry
from repro.obs.timing import SimTimer, WallTimer
from repro.runtime import (
    FailureScenario,
    FlinkHarness,
    HolonHarness,
    Scenario,
    SimConfig,
)
from repro.runtime.sim import Sim
from repro.streaming import make_q7

CFG = SimConfig(
    num_nodes=3, num_partitions=4, num_batches=60, window_len=500,
    sync_interval_ms=50.0, ckpt_interval_ms=300.0, obs=True,
)
HORIZON = CFG.horizon_ms + 10_000.0


def _q(cfg=CFG):
    return make_q7(cfg.num_partitions, window_len=cfg.window_len,
                   num_slots=cfg.num_slots)


def _run(cfg=CFG, scenario=None, harness_cls=HolonHarness, horizon=HORIZON):
    h = harness_cls(cfg, _q(cfg))
    h.run(scenario, horizon_ms=horizon)
    return h


# ---------------------------------------------------------------------------
# unit: records, ring, registry, timers, exporters
# ---------------------------------------------------------------------------
class TestRecords:
    def test_ring_bounds_and_dropped(self):
        buf = TraceBuffer(cap=8)
        for i in range(20):
            buf.append(TraceEvent(t_ms=float(i), kind="x"))
        assert len(buf.events()) == 8
        assert buf.total == 20
        assert buf.dropped == 12
        # oldest evicted: remaining records are the 8 newest
        assert [e.t_ms for e in buf.events()] == [float(i) for i in range(12, 20)]

    def test_mkargs_sorted_and_event_equality(self):
        assert mkargs(b=1, a=2) == (("a", 2), ("b", 1))
        e1 = TraceEvent(t_ms=1.0, kind="k", args=mkargs(x=1))
        e2 = TraceEvent(t_ms=1.0, kind="k", args=mkargs(x=1))
        assert e1 == e2 and e1.arg("x") == 1 and e1.arg("missing", 9) == 9

    def test_jsonl_header_and_order(self):
        buf = TraceBuffer(cap=4)
        buf.append(TraceEvent(t_ms=2.0, kind="b"))
        buf.append(TraceEvent(t_ms=1.0, kind="a"))
        out = to_jsonl(buf.events(), dropped=buf.dropped).splitlines()
        head = json.loads(out[0])
        assert head["meta"] == "holon-trace-v1" and head["dropped"] == 0
        # records come out in recording order, keys sorted inside each line
        assert json.loads(out[1])["kind"] == "b"
        assert list(json.loads(out[2])) == sorted(json.loads(out[2]))

    def test_chrome_span_vs_instant(self):
        evs = [
            TraceEvent(t_ms=1.0, kind="exec.batch", node=0, partition=2,
                       t_end_ms=3.0),
            TraceEvent(t_ms=4.0, kind="node.crash", node=1),
        ]
        doc = to_chrome(evs)
        by_ph = {e["ph"]: e for e in doc["traceEvents"] if e["ph"] in "Xi"}
        assert by_ph["X"]["dur"] == pytest.approx(2000.0)  # ms -> us
        assert by_ph["X"]["ts"] == pytest.approx(1000.0)
        assert by_ph["i"]["pid"] == 1
        assert doc["displayTimeUnit"] == "ms"

    def test_registry_key_sorted_and_histogram(self):
        reg = MetricsRegistry()
        reg.counter("c", node=2).inc(3)
        reg.counter("c", node=1).inc()
        h = reg.histogram("lat", phase="emit")
        for v in (0.5, 3.0, 100.0):
            h.observe(v)
        got = reg.collect()
        assert list(got) == sorted(got)
        assert got["c{node=1}"] == 1 and got["c{node=2}"] == 3
        assert h.count == 3 and h.max == 100.0 and h.min == 0.5
        assert h.percentile(99) <= h.max
        assert reg.histograms("lat") == {"lat{phase=emit}": h}

    def test_snapshot_series_on_sim_time(self):
        sim = Sim()
        tel = Telemetry(sim, on=True, snapshot_ms=10.0)
        tel.registry.counter("n").inc()
        tel.start_snapshots()
        sim.run(until=35.0)
        assert [t for t, _ in tel.registry.series] == [10.0, 20.0, 30.0]
        assert all(vals["n"] == 1 for _, vals in tel.registry.series)

    def test_summary_shared_keys(self):
        s = summary([1.0, 2.0, 3.0])
        assert set(s) == {"avg", "p50", "p99", "max", "n"}
        assert s["avg"] == pytest.approx(2.0) and s["n"] == 3

    def test_timers_domains(self):
        with WallTimer() as wt:
            pass
        assert wt.domain == "wall" and wt.dt >= 0.0
        sim = Sim()
        st = SimTimer(sim)
        with st:
            sim.after(5.0, lambda: None)
            sim.run(until=10.0)
        assert st.domain == "sim" and st.dt_ms == pytest.approx(10.0)

    def test_telemetry_off_records_nothing(self):
        sim = Sim()
        tel = Telemetry(sim)  # both switches off
        tel.event("emit", node=0)
        tel.net_msg(0, 1, "sync", 10.0, "ok")
        tel.start_snapshots()
        sim.run(until=2000.0)
        assert tel.buf.total == 0 and tel.registry.series == []


# ---------------------------------------------------------------------------
# determinism: byte-identical exports, on/off run-equivalence
# ---------------------------------------------------------------------------
CHAOS_CFG = dataclasses.replace(
    CFG, net_loss=0.05, net_jitter="uniform", net_jitter_ms=3.0
)
CHAOS_SCEN = (
    Scenario("crash_and_partition")
    .crash(1500.0, 0)
    .partition(2500.0, (1,), (2,))
    .heal(4000.0)
    .restart(4500.0, 0)
)


class TestDeterminism:
    @pytest.mark.parametrize("harness_cls", [HolonHarness, FlinkHarness])
    def test_same_seed_byte_identical_exports(self, harness_cls):
        h1 = _run(CHAOS_CFG, CHAOS_SCEN, harness_cls)
        h2 = _run(CHAOS_CFG, CHAOS_SCEN, harness_cls)
        assert h1.obs.buf.total > 0
        assert h1.obs.export_jsonl() == h2.obs.export_jsonl()
        assert json.dumps(h1.obs.export_chrome()) == json.dumps(
            h2.obs.export_chrome()
        )

    @pytest.mark.parametrize("harness_cls", [HolonHarness, FlinkHarness])
    def test_telemetry_does_not_perturb_run(self, harness_cls):
        off = dataclasses.replace(CHAOS_CFG, obs=False)
        h_on = _run(CHAOS_CFG, CHAOS_SCEN, harness_cls)
        h_off = _run(off, CHAOS_SCEN, harness_cls)
        assert h_off.obs.buf.total == 0
        c_on, c_off = h_on.consumer, h_off.consumer
        assert sorted(c_on.records) == sorted(c_off.records)
        for k in c_on.records:
            a, b = c_on.records[k], c_off.records[k]
            assert a.emit_time == b.emit_time and a.latency == b.latency
            if a.value is not None:
                assert np.array_equal(np.asarray(a.value), np.asarray(b.value))
        assert c_on.latency_stats() == c_off.latency_stats()

    def test_net_trace_equality_still_holds(self):
        # the PR-5 contract: fabric traces of same-seed runs compare equal
        cfg = dataclasses.replace(CHAOS_CFG, obs=False, net_trace=True)
        h1 = _run(cfg, CHAOS_SCEN)
        h2 = _run(cfg, CHAOS_SCEN)
        assert h1.net.trace and h1.net.trace == h2.net.trace


# ---------------------------------------------------------------------------
# auditor passes every tier-1 scenario family
# ---------------------------------------------------------------------------
SCENARIOS = {
    "baseline": None,
    "concurrent": FailureScenario.concurrent(t=2000.0),
    "subsequent": FailureScenario.subsequent(t=1500.0),
    "crash": FailureScenario.crash(t=2000.0),
    "partition_heal": Scenario("ph").partition(2000.0, (0,), (1, 2)).heal(3500.0),
    "elastic": Scenario("el").scale_out(2000.0, 3).scale_in(4000.0, 3),
}


class TestAuditorPasses:
    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_holon_clean(self, name):
        h = _run(scenario=SCENARIOS[name])
        rep = audit_harness(h)
        assert rep.ok, f"{name}: {rep}"
        assert rep.metrics["windows_accepted"] > 0

    @pytest.mark.parametrize("name", ["baseline", "concurrent", "partition_heal"])
    def test_flink_clean(self, name):
        h = _run(scenario=SCENARIOS[name], harness_cls=FlinkHarness)
        rep = audit_harness(h)
        assert rep.ok, f"{name}: {rep}"

    def test_recovery_metrics_extracted(self):
        h = _run(scenario=SCENARIOS["crash"])
        rep = audit_harness(h)
        ttr = rep.metrics["time_to_recover_ms"]
        bound = (CFG.hb_timeout_ms + 2 * CFG.hb_interval_ms + CFG.steal_delay_ms
                 + 2 * CFG.storage_rtt_ms + 250.0)
        assert ttr and all(0.0 < t <= bound for t in ttr.values())

    def test_flink_downtime_extracted(self):
        h = _run(scenario=SCENARIOS["concurrent"], harness_cls=FlinkHarness)
        rep = audit_harness(h)
        assert "flink_downtime_ms" in rep.metrics


# ---------------------------------------------------------------------------
# auditor mutation: seeded violations are each flagged
# ---------------------------------------------------------------------------
def _clean_events():
    h = _run(scenario=SCENARIOS["concurrent"])
    rep = audit_harness(h)
    assert rep.ok
    return list(h.obs.buf.events()), h.cfg


def _violations(events, cfg):
    return audit(events, cfg=cfg).violations


class TestAuditorMutations:
    def test_duplicate_emission_flagged(self):
        evs, cfg = _clean_events()
        first = next(e for e in evs if e.kind == "emit" and e.status == "accepted")
        evs.append(dataclasses.replace(first, t_ms=first.t_ms + 1.0))
        v = _violations(evs, cfg)
        assert any("[exactly-once]" in s and "accepted twice" in s for s in v)

    def test_divergent_duplicate_digest_flagged(self):
        evs, cfg = _clean_events()
        first = next(e for e in evs if e.kind == "emit" and e.status == "accepted")
        evs.append(dataclasses.replace(
            first, t_ms=first.t_ms + 1.0, status="duplicate",
            args=mkargs(digest=12345, latency_ms=0.0),
        ))
        v = _violations(evs, cfg)
        assert any("different value digest" in s for s in v)

    def test_frontier_regression_flagged(self):
        evs, cfg = _clean_events()
        applies = [e for e in evs if e.kind == "ckpt.apply"]
        last = max(applies, key=lambda e: (e.t_ms, e.arg("nxt_idx", 0)))
        evs.append(dataclasses.replace(
            last, t_ms=last.t_ms + 1.0, args=mkargs(nxt_idx=0, epoch=0),
        ))
        v = _violations(evs, cfg)
        assert any("[frontier-regression]" in s for s in v)

    def test_unacked_merge_flagged(self):
        evs, cfg = _clean_events()
        merge = next(e for e in evs
                     if e.kind == "sync.recv" and e.status == "delta_merge"
                     and e.arg("marker"))
        # a merge claiming a marker at an instant with no fabric ack record
        evs.append(dataclasses.replace(merge, t_ms=merge.t_ms + 0.123))
        v = _violations(evs, cfg)
        assert any("[unacked-merge]" in s for s in v)

    def test_non_dominated_merge_flagged(self):
        evs, cfg = _clean_events()
        merge = next(e for e in evs
                     if e.kind == "sync.recv" and e.status == "delta_merge")
        evs.append(dataclasses.replace(
            merge, t_ms=merge.t_ms + 0.125, args=mkargs(dominated=0, marker=0),
        ))
        v = _violations(evs, cfg)
        assert any("[domination]" in s for s in v)

    def test_truncated_ring_refused(self):
        evs, cfg = _clean_events()
        rep = audit(evs, cfg=cfg, dropped=7)
        assert not rep.ok
        assert any("[truncated]" in s for s in rep.violations)

    def test_clean_trace_stays_clean(self):
        # the mutation helpers start from a certified trace — pin that the
        # unmutated copy audits ok through the same path
        evs, cfg = _clean_events()
        assert audit(evs, cfg=cfg).ok
