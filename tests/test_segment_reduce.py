"""Sorted segment-reduce kernel: Pallas/ref parity, keyed-fold dispatch, and
the keyed max/min strip-mining peak-memory regression (DESIGN.md §5,
docs/protocol.md §6).

Parity convention: count/max/min are order-independent, so Pallas and ref are
compared exactly; float sums reduce in a different order on the two paths
(sorted chunks vs segment_sum), so op="sum" over arbitrary floats uses
allclose.  Counts themselves are sums of ones — exact small integers in f32 —
which is what the q5 byte-identity guarantees lean on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import SPARSE_KEY_THRESHOLD, segment_reduce, window_agg
from repro.kernels.ref import segment_reduce_ref, window_agg_ref
from repro.kernels.segment_reduce import segment_reduce_pallas
from repro.kernels.window_agg import window_agg_pallas

OPS = ("sum", "count", "max", "min")


def _compare(got, want, op):
    got, want = np.asarray(got), np.asarray(want)
    if op == "sum":
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    else:
        np.testing.assert_array_equal(got, want)


def _case(B, n_seg, seed, hot=False):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    vals = jax.random.normal(k1, (B,), jnp.float32) * 10.0
    if hot:  # zipf-ish: most lanes hit a few segments, many segments empty
        segs = jnp.minimum(
            jax.random.randint(k2, (B,), 0, 8), jax.random.randint(k2, (B,), 0, n_seg)
        )
    else:
        segs = jax.random.randint(k2, (B,), 0, n_seg)
    mask = jax.random.bernoulli(k3, 0.8, (B,))
    return vals, segs.astype(jnp.int32), mask


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("B,n_seg,hot", [
    (512, 513, False),   # n_seg not a tile multiple; many empty segments
    (1024, 64, False),   # dense small-domain
    (300, 2000, True),   # hot keys + a long empty tail of segments
])
def test_pallas_matches_ref(op, B, n_seg, hot):
    vals, segs, mask = _case(B, n_seg, 0, hot)
    got = segment_reduce_pallas(vals, segs, mask, n_seg, op=op, interpret=True)
    want = segment_reduce_ref(vals, segs, mask, n_seg, op=op)
    _compare(got, want, op)


@pytest.mark.parametrize("op", OPS)
def test_edge_segments(op):
    """All lanes on one segment, the last segment id, and a fully masked-off
    batch — the boundary cases of the sorted range computation."""
    B, n_seg = 256, 777
    # positive, non-cancelling values: a 256-term sum whose true value is ~0
    # would make any relative tolerance meaningless under reordering
    vals = jnp.linspace(0.5, 5.0, B)
    ones = jnp.ones((B,), bool)
    for segs, mask in [
        (jnp.zeros((B,), jnp.int32), ones),              # all-one-key
        (jnp.full((B,), n_seg - 1, jnp.int32), ones),    # key == C-1 (tile edge)
        (jnp.arange(B, dtype=jnp.int32) % n_seg, jnp.zeros((B,), bool)),  # no lanes
    ]:
        got = segment_reduce_pallas(vals, segs, mask, n_seg, op=op, interpret=True)
        want = segment_reduce_ref(vals, segs, mask, n_seg, op=op)
        _compare(got, want, op)
        # untouched segments must read the neutral element, not garbage
        neutral = {"sum": 0.0, "count": 0.0, "max": -np.inf, "min": np.inf}[op]
        untouched = np.setdiff1d(np.arange(n_seg), np.asarray(segs[mask]))
        if untouched.size:
            np.testing.assert_array_equal(np.asarray(got)[untouched], neutral)


@pytest.mark.parametrize("op", OPS)
def test_ops_wrapper_dispatch(op):
    vals, segs, mask = _case(640, 1500, 3)
    got = segment_reduce(vals, segs, mask, 1500, op=op, use_pallas=True, interpret=True)
    want = segment_reduce(vals, segs, mask, 1500, op=op, use_pallas=False)
    _compare(got, want, op)


def test_keyed_window_agg_dispatches_to_segment_reduce():
    """Above SPARSE_KEY_THRESHOLD the keyed fold rides the sorted kernel and
    still matches the dense jnp reference; below, the dense MXU kernel."""
    B, W = 512, 4
    C_big = SPARSE_KEY_THRESHOLD  # >= threshold -> sparse path
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    vals = jnp.ones((B,), jnp.float32)  # counts: exact in f32 on both paths
    slots = jax.random.randint(k1, (B,), 0, W)
    keys = jax.random.randint(k2, (B,), 0, C_big)
    mask = jax.random.bernoulli(k3, 0.9, (B,))
    got = window_agg(vals, slots, mask, W, op="sum", keys=keys, C=C_big,
                     use_pallas=True, interpret=True)
    want = window_agg_ref(vals, slots, mask, W, op="sum", keys=keys, C=C_big)
    assert got.shape == (W, C_big)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    C_small = 64  # < threshold -> dense kernel, bit-identical to before
    keys_s = keys % C_small
    got_s = window_agg(vals, slots, mask, W, op="sum", keys=keys_s, C=C_small,
                       use_pallas=True, interpret=True)
    want_s = window_agg_ref(vals, slots, mask, W, op="sum", keys=keys_s, C=C_small)
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(want_s))


def test_keyed_window_agg_rejects_i32_overflow():
    B = 8
    vals = jnp.ones((B,), jnp.float32)
    idx = jnp.zeros((B,), jnp.int32)
    with pytest.raises(ValueError, match="shard the key range"):
        window_agg(vals, idx, jnp.ones((B,), bool), 1024, op="sum",
                   keys=idx, C=2**21, use_pallas=True, interpret=True)


def test_keyed_maxmin_peak_memory_is_strip_mined():
    """Regression: the keyed max/min kernel must never materialize the
    [bt, W, C] broadcast — its largest live intermediate is the [bt, C]
    strip (plus the [W, C] accumulator).  Pinned by parsing the lowered HLO
    of the interpreted kernel and bounding the biggest instruction."""
    from repro.launch.hlo_analysis import parse_blocks

    bt, W, C = 256, 16, 512
    B = bt

    def f(vals, slots, keys, mask):
        return window_agg_pallas(vals, slots, mask, W, op="max", keys=keys,
                                 C=C, block_b=bt, interpret=True)

    args = (
        jnp.zeros((B,), jnp.float32), jnp.zeros((B,), jnp.int32),
        jnp.zeros((B,), jnp.int32), jnp.zeros((B,), bool),
    )
    text = jax.jit(f).lower(*args).as_text()
    blocks, _ = parse_blocks(text)
    biggest = max(
        (i.nbytes for b in blocks.values() for i in b.instrs), default=0
    )
    dense_broadcast = bt * W * C * 4
    assert biggest < dense_broadcast, (
        f"largest HLO value is {biggest}B >= the [bt, W, C] broadcast "
        f"({dense_broadcast}B) — keyed max/min lost its strip-mining"
    )
    # sanity: the parity above isn't vacuous — strip-mined output is correct
    vals, segs, mask = _case(B, C, 11)
    slots = segs % W
    got = window_agg_pallas(vals, slots, mask, W, op="max", keys=segs, C=C,
                            block_b=bt, interpret=True)
    want = window_agg_ref(vals, slots, mask, W, op="max", keys=segs, C=C)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
