"""Windowed-CRDT semantics (paper §3.3, §4.2).

Global determinism: once getWindowValue returns a value for window w, every
replica returns the SAME value for w, regardless of network order, delays,
or duplicated deliveries.  Incomplete windows read as not-ok (None).
"""
import jax
import jax.numpy as jnp
import numpy as np
from _prop import given, settings, st

from repro.core import wcrdt as W
from repro.core import wgcounter, wmaxreg, wtopk

settings.register_profile("ci-wcrdt", max_examples=30, deadline=None)
settings.load_profile("ci-wcrdt")

P = 3  # partitions
WL = 10  # window length
SLOTS = 8


def _mk_events(rng, n):
    """Per-partition ordered timestamps + values."""
    ts = np.sort(rng.integers(0, WL * 4, size=n)).astype(np.int32)
    vals = rng.random(n).astype(np.float32) * 10
    return ts, vals


@given(seed=st.integers(0, 2**20))
def test_global_determinism_gcounter(seed):
    rng = np.random.default_rng(seed)
    spec = wgcounter(WL, SLOTS, P)

    # each partition folds its own events into its replica
    replicas = []
    all_events = []
    for p in range(P):
        ts, vals = _mk_events(rng, int(rng.integers(4, 12)))
        all_events.append((ts, vals))
        s = spec.zero()
        s = W.insert(spec, s, p, jnp.array(ts), jnp.ones(len(ts), bool), actor=p, amounts=jnp.array(vals))
        s = W.increment_watermark(spec, s, p, int(ts.max()))
        replicas.append(s)

    # two different delivery orders (with duplication) must agree
    def sync(order, dup):
        states = [replicas[i] for i in range(P)]
        for src, dst in order:
            states[dst] = W.merge(spec, states[dst], states[src])
        for src, dst in dup:
            states[dst] = W.merge(spec, states[dst], states[src])
        return states

    full = [(i, j) for i in range(P) for j in range(P) if i != j]
    orderA = full
    orderB = full[::-1]
    dups = [full[rng.integers(0, len(full))] for _ in range(3)]
    sA = sync(orderA, dups)
    sB = sync(orderB, [])

    gwm = min(int(e[0].max()) for e in all_events)
    complete_windows = [w for w in range(4) if gwm >= (w + 1) * WL]
    for w in complete_windows:
        ref = None
        for states in (sA, sB):
            for s in states:
                v, ok = W.window_value(spec, s, w)
                assert bool(ok), f"window {w} should be complete"
                if ref is None:
                    ref = float(v)
                assert float(v) == ref
        # and it matches the oracle
        oracle = sum(
            float(vals[(ts >= w * WL) & (ts < (w + 1) * WL)].sum())
            for ts, vals in all_events
        )
        np.testing.assert_allclose(ref, oracle, rtol=1e-5)

    # incomplete windows read not-ok on every replica
    for w in range(4):
        if w not in complete_windows:
            for s in sA:
                _, ok = W.window_value(spec, s, w)
                assert not bool(ok)


@given(seed=st.integers(0, 2**20))
def test_watermark_monotone_and_safety(seed):
    rng = np.random.default_rng(seed)
    spec = wmaxreg(WL, SLOTS, P)
    s = spec.zero()
    last_gwm = -1
    for step in range(5):
        p = int(rng.integers(0, P))
        ts = np.sort(rng.integers(step * 5, step * 5 + 20, size=4)).astype(np.int32)
        s = W.insert(spec, s, p, jnp.array(ts), jnp.ones(4, bool), vals=jnp.array(rng.random(4), jnp.float32))
        s = W.increment_watermark(spec, s, p, int(ts.max()))
        gwm = int(W.global_watermark(spec, s))
        assert gwm >= last_gwm
        last_gwm = gwm
        # no window at/after the watermark reads complete
        w_edge = gwm // WL
        _, ok = W.window_value(spec, s, w_edge)  # window containing gwm
        if gwm < (w_edge + 1) * WL:
            assert not bool(ok)


def test_late_events_counted():
    spec = wgcounter(WL, SLOTS, P)
    s = spec.zero()
    s = W.increment_watermark(spec, s, 0, 25)
    ts = jnp.array([5, 30], jnp.int32)  # 5 is behind partition-0 watermark
    s = W.insert(spec, s, 0, ts, jnp.ones(2, bool), actor=0, amounts=jnp.ones(2))
    assert int(s.errors[W.ERR_LATE]) == 1
    # the late event must NOT be folded
    for p in range(P):
        s = W.increment_watermark(spec, s, p, 100)
    v, ok = W.window_value(spec, s, 0)
    assert bool(ok) and float(v) == 0.0


def test_ring_eviction_detected():
    spec = wgcounter(WL, 2, 1)  # tiny ring: 2 slots
    s = spec.zero()
    for w in range(4):  # windows 0..3 with ring of 2 -> evictions
        ts = jnp.array([w * WL + 1], jnp.int32)
        s = W.insert(spec, s, 0, ts, jnp.ones(1, bool), actor=0, amounts=jnp.ones(1))
    # window 0 evicted: value unreadable
    s = W.increment_watermark(spec, s, 0, 100)
    _, ok = W.window_value(spec, s, 0)
    assert not bool(ok)
    v3, ok3 = W.window_value(spec, s, 3)
    assert bool(ok3) and float(v3) == 1.0


@given(seed=st.integers(0, 2**20))
def test_topk_windowed_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    k = 4
    spec = wtopk(WL, SLOTS, 2, k)
    sA, sB = spec.zero(), spec.zero()
    events = []
    for p, s_ in ((0, "A"), (1, "B")):
        n = int(rng.integers(5, 20))
        ts = np.sort(rng.integers(0, WL * 3, size=n)).astype(np.int32)
        vals = (rng.random(n) * 100).astype(np.float32)
        ids = rng.integers(0, 1000, size=n).astype(np.uint32)
        events.append((ts, vals, ids))
    sA = W.insert(spec, sA, 0, jnp.array(events[0][0]), jnp.ones(len(events[0][0]), bool),
                  vals=jnp.array(events[0][1]), ids=jnp.array(events[0][2]))
    sA = W.increment_watermark(spec, sA, 0, int(events[0][0].max()))
    sB = W.insert(spec, sB, 1, jnp.array(events[1][0]), jnp.ones(len(events[1][0]), bool),
                  vals=jnp.array(events[1][1]), ids=jnp.array(events[1][2]))
    sB = W.increment_watermark(spec, sB, 1, int(events[1][0].max()))
    m = W.merge(spec, sA, sB)

    gwm = min(int(events[0][0].max()), int(events[1][0].max()))
    for w in range(3):
        if gwm >= (w + 1) * WL:
            (vals, ids), ok = W.window_value(spec, m, w)
            assert bool(ok)
            pool = []
            for ts, vv, ii in events:
                sel = (ts >= w * WL) & (ts < (w + 1) * WL)
                pool += list(zip(vv[sel].tolist(), ii[sel].tolist()))
            pool.sort(key=lambda t: (-t[0], -t[1]))
            expect = [v for v, _ in pool[:k]]
            got = [v for v in np.asarray(vals).tolist() if v > -np.inf]
            np.testing.assert_allclose(got[: len(expect)], expect, rtol=1e-5)
