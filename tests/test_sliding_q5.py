"""Nexmark Q5 hot items over sliding (hopping) windows, end-to-end.

Acceptance for the window-assigner refactor: q5 runs on both the
discrete-event harness and the shard_map dataplane, byte-identical to its
plain-jnp oracle, including under crash/restart — and the tumbling
degenerate of every generalized query keeps matching its oracle.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.runtime import FailureScenario, SimConfig, run_holon
from repro.runtime.flink_baseline import run_flink
from repro.streaming import NexmarkConfig, generate_log, make_q0, make_q5

CFG = SimConfig(
    num_nodes=3,
    num_partitions=6,
    num_batches=60,
    events_per_batch=256,
    rate_per_partition=10_000.0,
    window_len=500,
    num_slots=32,
    ckpt_interval_ms=300.0,
    sync_interval_ms=50.0,
)


def _log(cfg: SimConfig):
    return generate_log(NexmarkConfig(
        num_partitions=cfg.num_partitions, num_batches=cfg.num_batches,
        events_per_batch=cfg.events_per_batch,
        rate_per_partition=cfg.rate_per_partition, seed=cfg.seed,
    ))


def _q5(cfg: SimConfig, hop=None):
    return make_q5(cfg.num_partitions, window_len=cfg.window_len,
                   num_slots=cfg.num_slots, hop=hop)


def test_q5_harness_matches_oracle_byte_identical():
    q = _q5(CFG)
    assert q.assigner.windows_per_event == 2  # default hop = window/2
    c = run_holon(CFG, q)
    log = _log(CFG)
    wids = sorted({w for (_, w) in c.records})
    # overlapping windows close every hop: ids are dense, more than tumbling
    assert len(wids) > int(CFG.horizon_ms // CFG.window_len) - 1
    assert wids == list(range(len(wids)))
    assert len(c.records) == len(wids) * CFG.num_partitions
    for (pid, w), r in c.records.items():
        np.testing.assert_array_equal(
            np.asarray(r.value), np.asarray(q.oracle(log, w)), err_msg=str((pid, w))
        )


def test_q5_crash_restart_exactly_once():
    """Crash two nodes mid-stream, restart them, and require the overlapping-
    window output to be byte-identical to the failure-free oracle run."""
    q = _q5(CFG)
    oracle_run = run_holon(CFG, q)
    want = {k: np.asarray(r.value) for k, r in oracle_run.records.items()}
    assert want
    scen = FailureScenario.concurrent(t=600.0, nodes=(0, 1))
    got = run_holon(CFG, q, scen, horizon_ms=CFG.horizon_ms + 15_000)
    missing = set(want) - set(got.records)
    assert not missing, f"lost outputs {sorted(missing)[:5]}"
    for k, v in want.items():
        np.testing.assert_array_equal(np.asarray(got.records[k].value), v,
                                      err_msg=str(k))
    # and every emission matches the log oracle too
    log = _log(CFG)
    for (pid, w), r in got.records.items():
        np.testing.assert_array_equal(
            np.asarray(r.value), np.asarray(q.oracle(log, w))
        )


def test_q5_scale_out_in_exactly_once():
    """Elastic membership churn (scale-out then scale-in) over overlapping
    windows: deduplicated output equals the fixed-membership run."""
    from repro.runtime import Scenario

    q = _q5(CFG)
    want = {k: np.asarray(r.value)
            for k, r in run_holon(CFG, q).records.items()}
    scen = Scenario("elastic").scale_out(400.0, 3).scale_in(900.0, 3)
    got = run_holon(CFG, q, scen, horizon_ms=CFG.horizon_ms + 10_000)
    assert set(want) <= set(got.records)
    for k, v in want.items():
        np.testing.assert_array_equal(np.asarray(got.records[k].value), v)


def test_q5_sliding_latency_zero_point_is_window_end():
    """Consumer latency is measured from the assigner end_ts — window w
    closes at w*hop + window_len, not (w+1)*window_len."""
    q = _q5(CFG)
    c = run_holon(CFG, q)
    a = q.assigner
    some = next(iter(sorted(c.records)))
    rec = c.records[some]
    assert rec.latency >= 0.0
    assert c._close_ts(rec.window) == float(a.end_ts(rec.window))
    assert c._close_ts(1) == float(a.hop + a.window_len)


def test_q5_flink_baseline_runs_sliding():
    """The centralized baseline forwards per-assigner-complete windows, so
    the A/B comparison covers overlapping windows too (emission times only;
    the baseline models coordination, not values)."""
    q = _q5(CFG)
    c = run_flink(CFG, q)
    wids = sorted({w for (_, w) in c.records})
    assert len(wids) > int(CFG.horizon_ms // CFG.window_len) - 1


def test_q5_tumbling_degenerate_matches_oracle():
    """hop=window_len collapses q5 to tumbling and stays oracle-exact."""
    q = _q5(CFG, hop=CFG.window_len)
    assert q.assigner.windows_per_event == 1
    c = run_holon(CFG, q)
    log = _log(CFG)
    assert c.records
    for (pid, w), r in c.records.items():
        np.testing.assert_array_equal(
            np.asarray(r.value), np.asarray(q.oracle(log, w))
        )


def test_q0_harness_still_matches_oracle():
    """q0 (no shared state) under the generalized emission loop."""
    q = make_q0(CFG.num_partitions, window_len=CFG.window_len,
                num_slots=CFG.num_slots)
    c = run_holon(CFG, q)
    log = _log(CFG)
    assert c.records
    for (pid, w), r in c.records.items():
        np.testing.assert_array_equal(
            np.asarray(r.value).reshape(()),
            np.asarray(q.oracle(log, w, partition=pid)),
            err_msg=str((pid, w)),
        )


# ---------------------------------------------------------------------------
# shard_map dataplane (single device here; multidevice in the marked test)
# ---------------------------------------------------------------------------


def _dataplane_case(query_name: str, hop: int | None, delta_sync: bool = True):
    from repro import compat
    from repro.launch.stream import MAKERS, build_pipeline, read_window_range

    n_dev = 1
    batches, epb = 32, 1024
    mesh = compat.make_mesh((n_dev,), ("data",))
    nx = NexmarkConfig(num_partitions=n_dev, num_batches=batches,
                       events_per_batch=epb)
    log = generate_log(nx)
    kw = {"hop": hop} if hop else {}
    q = MAKERS[query_name](n_dev, window_len=1000, num_slots=64, **kw)
    first, n_windows = read_window_range(q, batches * nx.batch_span_ms)
    assert first == 0  # short horizon: nothing evicted yet
    with mesh:
        oks, vals, sb = build_pipeline(
            q, mesh, sync_every=4, delta_sync=delta_sync, n_windows=n_windows
        )(log)
    return q, log, np.asarray(oks)[0], np.asarray(vals)[0], np.asarray(sb)


def test_q5_dataplane_matches_oracle_byte_identical():
    q, log, oks, vals, sb = _dataplane_case("q5", hop=None)
    assert q.assigner.windows_per_event == 2
    assert oks.sum() >= 4  # sliding windows close every hop
    for w in np.nonzero(oks)[0]:
        np.testing.assert_array_equal(vals[w], np.asarray(q.oracle(log, int(w))))
    assert float(sb.sum()) > 0  # sliding-window sync bytes are measured


def test_q0_dataplane_runs_without_shared_state():
    """MAKERS includes q0; the empty-shared sync path is a no-op (0 bytes)."""
    q, log, oks, vals, sb = _dataplane_case("q0", hop=None)
    assert oks.sum() >= 2
    for w in np.nonzero(oks)[0]:
        np.testing.assert_array_equal(
            vals[w].reshape(()), np.asarray(q.oracle(log, int(w), partition=0))
        )
    assert float(sb.sum()) == 0.0


@pytest.mark.multidevice
def test_q5_dataplane_multidevice_subprocess():
    """4-device shard_map run of the sliding q5: delta sync byte-identical
    to full-state sync, every complete window oracle-exact."""
    import os
    import subprocess
    import sys
    from pathlib import Path

    script = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
from repro import compat
from repro.launch.stream import MAKERS, build_pipeline, read_window_range
from repro.streaming import NexmarkConfig, generate_log

n_dev = len(jax.devices()); assert n_dev == 4, n_dev
mesh = compat.make_mesh((n_dev,), ("data",))
nx = NexmarkConfig(num_partitions=n_dev, num_batches=24, events_per_batch=512)
log = generate_log(nx)
q = MAKERS["q5"](n_dev, window_len=200, num_slots=64)
first, n_windows = read_window_range(q, 24 * nx.batch_span_ms)
assert first == 0
with mesh:
    od, vd, sd = build_pipeline(q, mesh, 4, delta_sync=True, n_windows=n_windows)(log)
    of, vf, sf = build_pipeline(q, mesh, 4, delta_sync=False, n_windows=n_windows)(log)
np.testing.assert_array_equal(np.asarray(od), np.asarray(of))
np.testing.assert_array_equal(np.asarray(vd), np.asarray(vf))
od, vd = np.asarray(od)[0], np.asarray(vd)[0]
assert od.sum() >= 4
for w in np.nonzero(od)[0]:
    np.testing.assert_array_equal(vd[w], np.asarray(q.oracle(log, int(w))))
print("MULTIDEV_Q5_OK")
"""
    src = Path(__file__).resolve().parents[1] / "src"
    env = dict(os.environ, PYTHONPATH=str(src))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=600, env=env)
    assert "MULTIDEV_Q5_OK" in r.stdout, (
        f"stdout={r.stdout[-2000:]}\nstderr={r.stderr[-2000:]}"
    )


def test_q5_delta_sync_matches_full_state_on_harness():
    """Sliding windows ride the delta protocol unchanged: identical outputs,
    fewer bytes (the generalized dirty rule stays exact)."""
    q = _q5(CFG)
    delta = run_holon(CFG, q)
    full = run_holon(dataclasses.replace(CFG, delta_sync=False), q)
    dv = {k: np.asarray(r.value) for k, r in delta.records.items()}
    fv = {k: np.asarray(r.value) for k, r in full.records.items()}
    assert set(dv) == set(fv) and dv
    for k in dv:
        np.testing.assert_array_equal(dv[k], fv[k], err_msg=str(k))
    assert delta.sync_bytes < 0.6 * delta.sync_bytes_full


def test_q7_sliding_topk_active_clamped_to_ring():
    """make_q7's K-scaled topk_active is clamped to num_slots (more active
    offsets than slots would alias wid % W and silently drop folds), and
    the clamped fast path matches the exact slow path fold-for-fold."""
    import jax.numpy as jnp

    from repro.core import wcrdt as W
    from repro.core.window import Hopping
    from repro.streaming import make_q7

    q = make_q7(1, window_len=1000, num_slots=16, hop=125)  # K=8 -> 4*8=32
    spec = q.shared_specs[0]
    assert spec.max_active_windows == 16  # clamped, not 32
    with pytest.raises(ValueError):
        W.wtopk(1000, 16, 1, k=4, max_active_windows=32)

    a = Hopping(1000, 125)
    fast = W.wtopk(1000, 16, 1, k=4, max_active_windows=16, assigner=a)
    slow = W.wtopk(1000, 16, 1, k=4, max_active_windows=None, assigner=a)
    rng = np.random.default_rng(0)
    n = 64
    ts = jnp.array(np.sort(rng.integers(0, 1500, size=n)).astype(np.int32))
    vals = jnp.array((rng.random(n) * 100).astype(np.float32))
    ids = jnp.array(rng.integers(0, 1000, size=n).astype(np.uint32))
    sf = W.insert(fast, fast.zero(), 0, ts, jnp.ones(n, bool), vals=vals, ids=ids)
    ss = W.insert(slow, slow.zero(), 0, ts, jnp.ones(n, bool), vals=vals, ids=ids)
    sf = W.increment_watermark(fast, sf, 0, 3000)
    ss = W.increment_watermark(slow, ss, 0, 3000)
    for wid in range(int(ts.max()) // 125 + 1):
        (fv, fi), fok = W.window_value(fast, sf, wid)
        (sv, si), sok = W.window_value(slow, ss, wid)
        assert bool(fok) == bool(sok)
        if bool(fok):
            np.testing.assert_array_equal(np.asarray(fv), np.asarray(sv), err_msg=str(wid))
