"""Integration test of the dry-run machinery on a tiny mesh in a subprocess
(the 512-device flag must not leak into this test session)."""
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src"

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch import shardings as sh
    from repro.launch.mesh import make_mesh
    from repro.launch.hlo_analysis import analyze
    from repro.models import lm, flags
    from repro.training.train_step import make_train_step
    from repro.training.optimizer import AdamWState

    flags.set_tp_pad(2)
    cfg = get_config("deepseek_7b").reduced()
    mesh = make_mesh((4, 2), ("data", "model"))
    abs_params = jax.eval_shape(lambda k: lm.init_params(cfg, k, jnp.float32), jax.random.PRNGKey(0))
    p_shard = sh.shard_params(abs_params, mesh, cfg)
    toks = jax.ShapeDtypeStruct((8, 64), jnp.int32)
    in_shard = sh.shard_inputs({"tokens": toks}, mesh)
    abs_opt = jax.eval_shape(
        lambda p: AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p),
            nu=jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p),
        ),
        abs_params,
    )
    opt_shard = AdamWState(step=sh.replicated(mesh), mu=p_shard, nu=p_shard)
    step = make_train_step(cfg, q_chunk=32, ssm_chunk=16)
    with mesh:
        lowered = jax.jit(step, in_shardings=(p_shard, opt_shard, in_shard)).lower(
            abs_params, abs_opt, {"tokens": toks}
        )
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    res = analyze(compiled.as_text())
    assert res["flops"] > 0, "trip-count-aware flops should be nonzero"
    assert res["collective_bytes"]["total"] > 0, "TP psums expected"
    print("MINI_DRYRUN_OK", res["flops"], res["collective_bytes"]["total"])
    """
)


def test_mini_dryrun_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin", "HOME": "/root"},
    )
    assert "MINI_DRYRUN_OK" in r.stdout, f"stdout={r.stdout[-2000:]}\nstderr={r.stderr[-2000:]}"
