"""Per-architecture smoke tests: reduced config, one forward/train step and a
prefill+decode step on CPU, asserting shapes and finiteness (assignment
requirement).  The FULL configs are exercised only by the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import (
    cache_spec,
    decode_step,
    forward_loss,
    init_params,
    prefill,
    prefill_encdec,
)
from repro.training import adamw_init
from repro.training.train_step import make_train_step

ARCHS = list_archs()


def _batch(cfg, key, B=2, S=64):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks}
    if cfg.family == "audio":
        batch["enc_embeds"] = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(key, (B, 16, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss_finite(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key, dtype=jnp.float32)
    batch = _batch(cfg, key)
    loss = jax.jit(lambda p, b: forward_loss(cfg, p, b, q_chunk=32, ssm_chunk=16))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch} loss={loss}"
    assert 1.0 < float(loss) < 20.0  # ~ln(vocab) at init


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_updates_params(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key, dtype=jnp.float32)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, q_chunk=32, ssm_chunk=16, lr=1e-3))
    batch = _batch(cfg, key)
    new_params, new_opt, stats = step(params, opt, batch)
    assert jnp.isfinite(stats["loss"])
    assert jnp.isfinite(stats["grad_norm"]) and float(stats["grad_norm"]) > 0
    assert int(new_opt.step) == 1
    # at least one parameter actually moved
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved, f"{arch}: no parameter changed"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_shapes(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key, dtype=jnp.float32)
    B, S = 2, 64
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    if cfg.family == "audio":
        enc = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        logits, cache, enc_kv = jax.jit(
            lambda p, e, t: prefill_encdec(cfg, p, e, t, q_chunk=32)
        )(params, enc, toks)
        assert logits.shape == (B, 1, cfg.vocab)
        lg, cache = jax.jit(lambda p, c, t, e: decode_step(cfg, p, c, t, S, enc_kv=e))(
            params, cache, toks[:, :1], enc_kv
        )
    else:
        logits, cache = jax.jit(lambda p, t: prefill(cfg, p, t, q_chunk=32, ssm_chunk=16))(
            params, toks
        )
        assert logits.shape == (B, 1, cfg.vocab)
        lg, cache = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t, S))(
            params, cache, toks[:, :1]
        )
    assert lg.shape == (B, 1, cfg.vocab)
    assert jnp.all(jnp.isfinite(lg)), arch


def test_decode_matches_prefill_next_token():
    """Decoding the last prompt token against a cache prefilled with the
    preceding tokens reproduces the teacher-forced (prefill) logits."""
    cfg = get_config("deepseek_7b").reduced()
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key, dtype=jnp.float32)
    B, S = 1, 32
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    # teacher-forced logits at the last position
    logits_pre, _ = prefill(cfg, params, toks, q_chunk=16)
    # prefill S-1 tokens, pad the cache time axis to S, decode token S-1
    _, cache = prefill(cfg, params, toks[:, : S - 1], q_chunk=16)
    cache = jax.tree.map(
        lambda x: jnp.pad(x, [(0, 0), (0, 0), (0, 1), (0, 0), (0, 0)])
        if x.ndim == 5
        else x,
        cache,
    )
    lg, _ = decode_step(cfg, params, cache, toks[:, S - 1 :], S - 1)
    np.testing.assert_allclose(
        np.asarray(lg[0, 0]), np.asarray(logits_pre[0, 0]), rtol=2e-3, atol=2e-3
    )


def test_exact_config_fields():
    """The full (non-reduced) configs carry the assigned hyperparameters."""
    spec = {
        "minitron_4b": dict(n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=9216, vocab=256_000),
        "deepseek_7b": dict(n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=11008, vocab=102_400),
        "deepseek_coder_33b": dict(n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=19200, vocab=32_256),
        "mistral_large_123b": dict(n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=28672, vocab=32_768),
        "llama4_scout_17b_a16e": dict(n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, vocab=202_048, moe_experts=16, moe_top_k=1),
        "qwen3_moe_235b_a22b": dict(n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, vocab=151_936, moe_experts=128, moe_top_k=8, moe_d_ff=1536),
        "zamba2_7b": dict(n_layers=81, d_model=3584, n_heads=32, d_ff=14336, vocab=32_000, ssm_state=64),
        "falcon_mamba_7b": dict(n_layers=64, d_model=4096, vocab=65_024, ssm_state=16),
        "seamless_m4t_large_v2": dict(d_model=1024, n_heads=16, d_ff=8192, vocab=256_206),
        "pixtral_12b": dict(n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=14336, vocab=131_072),
    }
    for arch, fields in spec.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"
