"""Sharding-assignment unit tests (no multi-device runtime needed)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import shardings as sh
from repro.launch.mesh import make_mesh


def _mesh44():
    import numpy as np

    # abstract 4x4 mesh over the single CPU device would fail; build specs
    # via the helper functions with a fake sizes dict instead.
    return {"data": 4, "model": 4}


def test_fit_drops_nondividing_axes():
    sizes = _mesh44()
    # 24 heads on a 4-way axis: 24 % 4 == 0 -> kept
    assert sh._fit(("data", "model"), (8, 24), sizes) == P("data", "model")
    # 6 % 4 != 0 -> dropped to None
    assert sh._fit(("data", "model"), (8, 6), sizes) == P("data", None)
    # leading dims padded with None
    assert sh._fit(("model",), (3, 5, 8), sizes) == P(None, None, "model")


def test_param_pspec_attention_tp_gate():
    sizes = _mesh44()
    cfg_ok = get_config("deepseek_7b")  # 32 heads % 4 == 0
    cfg_bad = get_config("minitron_4b")  # 24 % 4 == 0 too; use 4->16 instead
    sizes16 = {"data": 16, "model": 16}

    class Leaf:
        def __init__(self, shape):
            self.shape = shape
            self.ndim = len(shape)

    path = (jax.tree_util.DictKey("layers"), jax.tree_util.DictKey("attn"), jax.tree_util.DictKey("wq"))
    # deepseek 32 heads on 16-way: TP kept
    spec = sh.param_pspec(path, Leaf((30, 4096, 4096)), sizes16, cfg_ok)
    assert spec == P(None, "data", "model")
    # minitron 24 heads on 16-way: TP dropped for wq (data kept)
    spec = sh.param_pspec(path, Leaf((32, 3072, 4096)), sizes16, cfg_bad)
    assert spec == P(None, "data", None)


def test_moe_expert_weights_ep_sharded():
    sizes16 = {"data": 16, "model": 16}
    cfg = get_config("qwen3_moe_235b_a22b")

    class Leaf:
        def __init__(self, shape):
            self.shape = shape
            self.ndim = len(shape)

    path = (
        jax.tree_util.DictKey("layers"),
        jax.tree_util.DictKey("moe"),
        jax.tree_util.DictKey("w_gate"),
    )
    spec = sh.param_pspec(path, Leaf((94, 128, 4096, 1536)), sizes16, cfg)
    assert spec == P(None, "model", "data", None)  # E over model (EP), D over data


def test_embed_vocab_parallel():
    sizes16 = {"data": 16, "model": 16}
    cfg = get_config("minitron_4b")

    class Leaf:
        def __init__(self, shape):
            self.shape = shape
            self.ndim = len(shape)

    path = (jax.tree_util.DictKey("embed"),)
    spec = sh.param_pspec(path, Leaf((256000, 3072)), sizes16, cfg)
    assert spec == P("model", None)


def test_shard_helper_noop_without_mesh():
    from repro.models.common import shard

    x = jnp.ones((4, 6))
    y = shard(x, "data", "model")  # no mesh active -> identity
    assert y.shape == x.shape


def test_pad_heads_flag():
    from repro.models import flags

    flags.set_tp_pad(16)
    try:
        assert flags.pad_heads(24) == 32
        assert flags.pad_heads(56) == 64
        assert flags.pad_heads(64) == 64
    finally:
        flags.set_tp_pad(1)
    assert flags.pad_heads(24) == 24
