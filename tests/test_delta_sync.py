"""Delta-based WCRDT sync (paper §7 future work, DESIGN.md §6): incremental
deltas apply exactly like full-state merges while shipping only dirty window
slots — property-tested over randomized fold/watermark schedules, and
end-to-end through the runtime (crash mid-sync, restart, byte-identical
output)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core import wcrdt as W
from repro.core import wgcounter, wtopk

settings.register_profile("ci-delta", max_examples=25, deadline=None)
settings.load_profile("ci-delta")


def leaves_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)


def test_delta_merge_equals_full_merge():
    spec = wgcounter(window_len=10, num_slots=16, num_partitions=2)
    a = spec.zero()  # partition 0's replica
    b = spec.zero()  # partition 1's replica (the receiver)

    # receiver knows a's state after 2 batches
    for idx, ts in enumerate(([1, 3, 7], [12, 15, 18])):
        t = jnp.array(ts, jnp.int32)
        a = W.insert(spec, a, 0, t, jnp.ones(len(ts), bool), batch_idx=idx,
                     actor=0, amounts=jnp.ones(len(ts)))
        a = W.increment_watermark(spec, a, 0, int(t.max()))
    b = W.merge(spec, b, a)
    baseline, base_prog = a.folded, a.progress

    # a folds two more batches (touching windows 1 and 2 only)
    for idx, ts in enumerate(([19, 22], [25, 29]), start=2):
        t = jnp.array(ts, jnp.int32)
        a = W.insert(spec, a, 0, t, jnp.ones(len(ts), bool), batch_idx=idx,
                     actor=0, amounts=jnp.ones(len(ts)))
        a = W.increment_watermark(spec, a, 0, int(t.max()))

    delta = W.delta_since(spec, a, baseline, base_prog)
    # delta carries fewer resident slots than the full state
    assert int((delta.slot_wid >= 0).sum()) < int((a.slot_wid >= 0).sum()) or \
        int((a.slot_wid >= 0).sum()) <= 2
    # merging the delta reproduces the full-state merge exactly
    via_delta = W.merge(spec, b, delta)
    via_full = W.merge(spec, b, a)
    leaves_equal(via_delta, via_full)

    # and the wire size is smaller than the full ring
    full_bytes = sum(l.nbytes for l in jax.tree.leaves(a.windows))
    d_bytes = float(W.delta_nbytes(delta))
    assert d_bytes < full_bytes


# ---------------------------------------------------------------------------
# Delta laws under randomized fold/watermark schedules
# ---------------------------------------------------------------------------

WL, SLOTS, PARTS = 10, 16, 3


def _spec(kind):
    if kind == "topk":
        return wtopk(WL, SLOTS, PARTS, k=4, max_active_windows=None)
    return wgcounter(WL, SLOTS, PARTS)


def _fold(spec, kind, state, p, ts, idx):
    t = jnp.array(ts, jnp.int32)
    m = jnp.ones(len(ts), bool)
    if kind == "topk":
        state = W.insert(spec, state, p, t, m, batch_idx=idx,
                         vals=jnp.arange(1.0, len(ts) + 1.0),
                         ids=jnp.arange(len(ts), dtype=jnp.uint32) + idx * 100)
    else:
        state = W.insert(spec, state, p, t, m, batch_idx=idx,
                         actor=p, amounts=jnp.ones(len(ts)))
    return W.increment_watermark(spec, state, p, int(max(ts)))


def _schedule(rng, n_batches):
    """Random in-order-per-partition fold schedule: (partition, [ts...])."""
    clock = [0] * PARTS
    out = []
    for _ in range(n_batches):
        p = rng.randint(0, PARTS - 1)
        n = rng.randint(1, 4)
        ts = []
        for _ in range(n):
            clock[p] += rng.randint(0, 7)
            ts.append(clock[p])
        out.append((p, ts))
    return out


@given(seed=st.integers(0, 2**20), kind=st.sampled_from(["gcounter", "topk"]),
       cut=st.integers(1, 6), extra=st.integers(1, 6))
def test_delta_merge_law_random_schedules(seed, kind, cut, extra):
    """merge(b, delta_since(a, base)) == merge(b, a) whenever b holds a's
    baseline state — for any in-order fold/watermark schedule."""
    import random

    rng = random.Random(seed)
    spec = _spec(kind)
    a = spec.zero()
    for idx, (p, ts) in enumerate(_schedule(rng, cut)):
        a = _fold(spec, kind, a, p, ts, idx)
    b = W.merge(spec, spec.zero(), a)  # receiver caught up to the baseline
    base_folded, base_prog = np.asarray(a.folded), np.asarray(a.progress)

    for idx, (p, ts) in enumerate(_schedule(rng, extra), start=cut):
        a = _fold(spec, kind, a, p, ts, idx)

    delta = W.delta_since(spec, a, base_folded, base_prog)
    via_delta = W.merge(spec, b, delta)
    via_full = W.merge(spec, b, a)
    leaves_equal(via_delta, via_full)
    # the delta is a point below a in the lattice: merging it into a is a no-op
    leaves_equal(W.merge(spec, a, delta), a)


@given(seed=st.integers(0, 2**20))
def test_delta_idempotent_and_commutes_with_concurrent_deltas(seed):
    """Applying a delta twice is a no-op, and concurrent senders' deltas
    merge to the same state in either order."""
    import random

    rng = random.Random(seed)
    spec = _spec("gcounter")

    def writer(p, n, off):
        s = spec.zero()
        for idx, (_, ts) in enumerate(_schedule(random.Random(seed + off), n)):
            s = _fold(spec, "gcounter", s, p, ts, idx)
        return s

    a = writer(0, rng.randint(1, 5), 1)
    c = writer(1, rng.randint(1, 5), 2)
    zb = W.zero_baseline(spec)
    da = W.delta_since(spec, a, *zb)
    dc = W.delta_since(spec, c, *zb)

    b = spec.zero()
    once = W.merge(spec, b, da)
    twice = W.merge(spec, once, da)
    leaves_equal(once, twice)

    ab = W.merge(spec, W.merge(spec, b, da), dc)
    ba = W.merge(spec, W.merge(spec, b, dc), da)
    leaves_equal(ab, ba)
    # and the pair of zero-baseline deltas reconstructs the full join
    leaves_equal(ab, W.merge(spec, a, c))


def test_delta_of_unchanged_state_is_identity_sized():
    spec = wgcounter(window_len=10, num_slots=16, num_partitions=2)
    a = spec.zero()
    t = jnp.array([1, 5], jnp.int32)
    a = W.insert(spec, a, 0, t, jnp.ones(2, bool), batch_idx=0, actor=0, amounts=jnp.ones(2))
    delta = W.delta_since(spec, a, a.folded, a.progress)  # receiver caught up
    assert int((delta.slot_wid >= 0).sum()) == 0  # no dirty slots
    # still merges as a no-op
    b = W.merge(spec, spec.zero(), a)
    b2 = W.merge(spec, b, delta)
    leaves_equal(b, b2)


# ---------------------------------------------------------------------------
# End-to-end: the runtime ships deltas; chaos mid-sync keeps exactly-once
# ---------------------------------------------------------------------------

from repro.runtime import FailureScenario, SimConfig, run_holon  # noqa: E402
from repro.streaming import make_q1_ratio, make_q7  # noqa: E402

CHAOS = SimConfig(
    num_nodes=3,
    num_partitions=6,
    num_batches=50,
    events_per_batch=256,
    rate_per_partition=10_000.0,
    window_len=500,
    num_slots=32,
    ckpt_interval_ms=250.0,
    sync_interval_ms=50.0,
)


def _values(consumer):
    return {k: np.asarray(r.value) for k, r in consumer.records.items()}


def test_runtime_delta_sync_matches_full_state_sync():
    """The delta protocol is pure optimization: identical outputs, a
    fraction of the sync bytes."""
    q = make_q7(CHAOS.num_partitions, window_len=CHAOS.window_len, num_slots=CHAOS.num_slots)
    delta = run_holon(CHAOS, q)
    full = run_holon(dataclasses.replace(CHAOS, delta_sync=False), q)
    dv, fv = _values(delta), _values(full)
    assert set(dv) == set(fv) and len(dv) > 0
    for k in dv:
        np.testing.assert_array_equal(dv[k], fv[k], err_msg=str(k))
    assert delta.sync_bytes < 0.25 * delta.sync_bytes_full
    assert full.sync_bytes == full.sync_bytes_full


def test_chaos_crash_mid_sync_exactly_once():
    """Crash a node while its deltas are still in flight (fail time lands
    between a sync publish and its deliveries), restart it, and require the
    consumer output to be byte-identical to the failure-free oracle."""
    q = make_q7(CHAOS.num_partitions, window_len=CHAOS.window_len, num_slots=CHAOS.num_slots)
    oracle = _values(run_holon(CHAOS, q))
    assert len(oracle) > 0
    # sync publishes land at k*sync_interval; broadcast_delay_ms = 5 puts
    # deliveries at +5 — failing at +2 kills the sender mid-flight
    mid_flight = 12 * CHAOS.sync_interval_ms + 2.0
    for scen in (
        FailureScenario(name="sender", fail_times_ms=(mid_flight,),
                        fail_nodes=(0,), restart_times_ms=(mid_flight + 700.0,)),
        FailureScenario(name="receiver", fail_times_ms=(mid_flight + 1.0,),
                        fail_nodes=(1,), restart_times_ms=(mid_flight + 900.0,)),
        FailureScenario(name="both", fail_times_ms=(mid_flight, mid_flight + 1.0),
                        fail_nodes=(0, 1),
                        restart_times_ms=(mid_flight + 700.0, mid_flight + 900.0)),
    ):
        got = _values(run_holon(CHAOS, q, scen))
        missing = set(oracle) - set(got)
        assert not missing, f"{scen.name}: lost outputs {sorted(missing)[:5]}"
        for k in oracle:
            np.testing.assert_array_equal(got[k], oracle[k],
                                          err_msg=f"{scen.name}:{k}")


def test_chaos_recovery_resyncs_after_stale_checkpoint():
    """A restarted node recovers an old checkpoint; peers' deltas assume a
    newer baseline, so the node must nack into a full resync — and outputs
    must still match the oracle (q1_ratio exercises local+shared state)."""
    q = make_q1_ratio(CHAOS.num_partitions, window_len=CHAOS.window_len,
                      num_slots=CHAOS.num_slots)
    cfg = dataclasses.replace(CHAOS, ckpt_interval_ms=600.0)  # stale ckpts
    oracle = _values(run_holon(cfg, q))
    mid_flight = 20 * cfg.sync_interval_ms + 2.0
    scen = FailureScenario(name="stale", fail_times_ms=(mid_flight,),
                           fail_nodes=(2,), restart_times_ms=(mid_flight + 1200.0,))
    c = run_holon(cfg, q, scen)
    got = _values(c)
    assert set(oracle) <= set(got)
    for k in oracle:
        np.testing.assert_array_equal(got[k], oracle[k], err_msg=str(k))


_MULTIDEV_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, numpy as np
from repro import compat
from repro.launch.stream import MAKERS, build_pipeline
from repro.streaming import NexmarkConfig, generate_log

n_dev = len(jax.devices()); assert n_dev == 4, n_dev
mesh = compat.make_mesh((n_dev,), ("data",))
nx = NexmarkConfig(num_partitions=n_dev, num_batches=16, events_per_batch=512)
log = generate_log(nx)
for qn in ("q1_ratio", "q7"):
    q = MAKERS[qn](n_dev, window_len=1000, num_slots=64)
    with mesh:
        od, vd, sd = build_pipeline(q, mesh, 4, delta_sync=True)(log)
        of, vf, sf = build_pipeline(q, mesh, 4, delta_sync=False)(log)
    np.testing.assert_array_equal(np.asarray(od), np.asarray(of))
    np.testing.assert_array_equal(np.asarray(vd), np.asarray(vf))
    assert float(np.asarray(sd).mean()) < 0.25 * float(np.asarray(sf).mean()), qn
print("MULTIDEV_DELTA_OK")
"""


@pytest.mark.multidevice
def test_stream_delta_sync_multidevice_subprocess():
    """Multi-device shard_map run: dirty-slot-gated exchange is
    output-identical to the full-state all-reduce at a fraction of the
    bytes (q7's TopK rides the generic join; q1_ratio the gated kernel)."""
    import os
    import subprocess
    import sys
    from pathlib import Path

    src = Path(__file__).resolve().parents[1] / "src"
    env = dict(os.environ, PYTHONPATH=str(src))
    env.pop("XLA_FLAGS", None)  # the script sets its own device count
    r = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_SCRIPT],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert "MULTIDEV_DELTA_OK" in r.stdout, (
        f"stdout={r.stdout[-2000:]}\nstderr={r.stderr[-2000:]}"
    )


def test_checkpoint_records_sync_baseline():
    """Checkpoints carry the delta-sync coverage marker of their snapshot."""
    from repro.runtime.harness import HolonHarness

    q = make_q7(CHAOS.num_partitions, window_len=CHAOS.window_len, num_slots=CHAOS.num_slots)
    h = HolonHarness(CHAOS, q)
    h.run()
    assert h.storage.has(0)
    ck = h.storage.get(0)
    assert ck.baseline is not None
    for (bf, bp), st in zip(ck.baseline, ck.shared):
        np.testing.assert_array_equal(bf, np.asarray(st.folded))
        np.testing.assert_array_equal(bp, np.asarray(st.progress))
