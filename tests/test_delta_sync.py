"""Delta-based WCRDT sync (paper §7 future work): incremental deltas apply
exactly like full-state merges while shipping only dirty window slots."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import wcrdt as W
from repro.core import wgcounter


def leaves_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)


def test_delta_merge_equals_full_merge():
    spec = wgcounter(window_len=10, num_slots=16, num_partitions=2)
    a = spec.zero()  # partition 0's replica
    b = spec.zero()  # partition 1's replica (the receiver)

    # receiver knows a's state after 2 batches
    for idx, ts in enumerate(([1, 3, 7], [12, 15, 18])):
        t = jnp.array(ts, jnp.int32)
        a = W.insert(spec, a, 0, t, jnp.ones(len(ts), bool), batch_idx=idx,
                     actor=0, amounts=jnp.ones(len(ts)))
        a = W.increment_watermark(spec, a, 0, int(t.max()))
    b = W.merge(spec, b, a)
    baseline, base_prog = a.folded, a.progress

    # a folds two more batches (touching windows 1 and 2 only)
    for idx, ts in enumerate(([19, 22], [25, 29]), start=2):
        t = jnp.array(ts, jnp.int32)
        a = W.insert(spec, a, 0, t, jnp.ones(len(ts), bool), batch_idx=idx,
                     actor=0, amounts=jnp.ones(len(ts)))
        a = W.increment_watermark(spec, a, 0, int(t.max()))

    delta = W.delta_since(spec, a, baseline, base_prog)
    # delta carries fewer resident slots than the full state
    assert int((delta.slot_wid >= 0).sum()) < int((a.slot_wid >= 0).sum()) or \
        int((a.slot_wid >= 0).sum()) <= 2
    # merging the delta reproduces the full-state merge exactly
    via_delta = W.merge(spec, b, delta)
    via_full = W.merge(spec, b, a)
    leaves_equal(via_delta, via_full)

    # and the wire size is smaller than the full ring
    full_bytes = sum(l.nbytes for l in jax.tree.leaves(a.windows))
    d_bytes = float(W.delta_nbytes(delta))
    assert d_bytes < full_bytes


def test_delta_of_unchanged_state_is_identity_sized():
    spec = wgcounter(window_len=10, num_slots=16, num_partitions=2)
    a = spec.zero()
    t = jnp.array([1, 5], jnp.int32)
    a = W.insert(spec, a, 0, t, jnp.ones(2, bool), batch_idx=0, actor=0, amounts=jnp.ones(2))
    delta = W.delta_since(spec, a, a.folded, a.progress)  # receiver caught up
    assert int((delta.slot_wid >= 0).sum()) == 0  # no dirty slots
    # still merges as a no-op
    b = W.merge(spec, spec.zero(), a)
    b2 = W.merge(spec, b, delta)
    leaves_equal(b, b2)
