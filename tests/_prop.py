"""Property-test shim: real hypothesis when installed, else a tiny seeded
fallback so tier-1 collects and passes on a bare interpreter.

The fallback implements exactly the subset these tests use:

* ``given(**strategies)`` — runs the test body for ``max_examples`` draws,
  each from a ``random.Random`` seeded by the test's qualified name (stable
  across runs and machines, so failures reproduce).
* ``settings.register_profile / load_profile`` with ``max_examples``.
* ``st.integers / floats / lists (incl. unique=) / tuples / booleans /
  sampled_from``.

No shrinking, no database — a failing draw reports its kwargs and the shim's
seed; install hypothesis for the full experience.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random
    import zlib

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _St:
        @staticmethod
        def integers(min_value=0, max_value=1 << 31):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, allow_nan=False, width=64,
                   allow_infinity=False):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def lists(elements, min_size=0, max_size=8, unique=False):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                if not unique:
                    return [elements.example(rng) for _ in range(n)]
                out: list = []
                attempts = 0
                while len(out) < n and attempts < 50 * n:  # bounded retry
                    attempts += 1
                    x = elements.example(rng)
                    if x not in out:
                        out.append(x)
                if len(out) < min_size:  # mirror hypothesis' Unsatisfiable
                    raise AssertionError(
                        f"lists(unique=True): drew only {len(out)} distinct "
                        f"elements, min_size={min_size}"
                    )
                return out

            return _Strategy(draw)

        @staticmethod
        def tuples(*strategies):
            return _Strategy(lambda rng: tuple(s.example(rng) for s in strategies))

    st = _St()

    class settings:  # noqa: N801 — mirrors hypothesis' name
        _profiles: dict = {"default": {"max_examples": 20}}
        _current = "default"

        def __init__(self, **kw):
            self.kw = kw

        @classmethod
        def register_profile(cls, name, **kw):
            cls._profiles[name] = kw

        @classmethod
        def load_profile(cls, name):
            cls._current = name

        @classmethod
        def _max_examples(cls):
            return cls._profiles.get(cls._current, {}).get("max_examples", 20)

    def given(**strategy_kwargs):
        def decorate(func):
            # snapshot the module's own profile at decoration time — several
            # test modules register/load a profile right before their @given
            # tests, and the registry is global (last import wins otherwise)
            max_examples = settings._max_examples()

            @functools.wraps(func)
            def wrapper(*args, **kwargs):
                seed0 = zlib.crc32(func.__qualname__.encode())
                for i in range(max_examples):
                    rng = random.Random(seed0 + i)
                    draws = {k: s.example(rng) for k, s in strategy_kwargs.items()}
                    try:
                        func(*args, **draws, **kwargs)
                    except Exception as e:  # annotate for reproduction
                        raise AssertionError(
                            f"falsifying example (shim seed {seed0 + i}): {draws!r}"
                        ) from e

            # hide the drawn params from pytest's fixture resolution
            sig = inspect.signature(func)
            wrapper.__signature__ = sig.replace(
                parameters=[
                    p for p in sig.parameters.values()
                    if p.name not in strategy_kwargs
                ]
            )
            return wrapper

        return decorate
