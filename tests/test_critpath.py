"""Critical-path analysis tests (src/repro/obs/critpath.py,
docs/observability.md §5).

Three layers:

* properties — on every tier-1 scenario family the analyzer reconstructs
  exactly one path per accepted emission, each path is a lower bound on the
  consumer-visible latency, phase attribution telescopes (phases sum to the
  path length exactly) and no phase goes negative;
* phase coverage — lossy/jittered links put real mass in the ``wire`` and
  ``loss_stall`` phases; sparse topologies stretch hop counts relative to
  all-to-all; the tree baseline attributes shuffle hops as wire time;
* determinism — same-seed chaos runs serialize byte-identical reports.
"""
from __future__ import annotations

import dataclasses
import json

import pytest

from repro.obs.critpath import PHASES, analyze, analyze_harness
from repro.obs.records import TraceEvent, mkargs
from repro.runtime import (
    FailureScenario,
    FlinkHarness,
    HolonHarness,
    Scenario,
    SimConfig,
)
from repro.streaming import make_q7

CFG = SimConfig(
    num_nodes=3, num_partitions=4, num_batches=60, window_len=500,
    sync_interval_ms=50.0, ckpt_interval_ms=300.0, obs=True,
)
HORIZON = CFG.horizon_ms + 10_000.0

CHAOS_CFG = dataclasses.replace(
    CFG, net_loss=0.05, net_jitter="uniform", net_jitter_ms=3.0
)
CHAOS_SCEN = (
    Scenario("crash_and_partition")
    .crash(1500.0, 0)
    .partition(2500.0, (1,), (2,))
    .heal(4000.0)
    .restart(4500.0, 0)
)

SCENARIOS = {
    "baseline": None,
    "concurrent": FailureScenario.concurrent(t=2000.0),
    "subsequent": FailureScenario.subsequent(t=1500.0),
    "crash": FailureScenario.crash(t=2000.0),
    "partition_heal": Scenario("ph").partition(2000.0, (0,), (1, 2)).heal(3500.0),
    "elastic": Scenario("el").scale_out(2000.0, 3).scale_in(4000.0, 3),
}


def _q(cfg=CFG):
    return make_q7(cfg.num_partitions, window_len=cfg.window_len,
                   num_slots=cfg.num_slots)


def _run(cfg=CFG, scenario=None, harness_cls=HolonHarness, horizon=HORIZON):
    h = harness_cls(cfg, _q(cfg))
    h.run(scenario, horizon_ms=horizon)
    return h


def _accepted(h) -> int:
    return sum(1 for e in h.obs.buf.events()
               if e.kind == "emit" and e.status == "accepted")


def _check_properties(report, accepted: int):
    """The §5 invariants every reconstructed path must satisfy."""
    assert len(report.paths) == accepted
    for p in report.paths:
        assert p.path_ms <= p.latency_ms + 1e-6, p
        assert sum(p.phases.values()) == pytest.approx(p.path_ms, abs=1e-6), p
        assert all(v >= -1e-9 for v in p.phases.values()), p
        assert set(p.phases) == set(PHASES)
        assert p.hops >= 0 and p.t_emit_ms >= 0.0


# ---------------------------------------------------------------------------
# properties on every tier-1 scenario family
# ---------------------------------------------------------------------------
class TestHolonProperties:
    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_path_invariants(self, name):
        h = _run(scenario=SCENARIOS[name])
        report = analyze_harness(h)
        assert report.system == "holon" and report.topology == "all"
        _check_properties(report, _accepted(h))
        s = report.summary()
        assert s["n"] == len(report.paths) > 0
        assert s["path_ms"]["max"] <= s["latency_ms"]["max"] + 1e-6

    def test_recovery_phase_on_adopted_checkpoint(self):
        # harness runs rarely leave an adopt elem as the gating lane (the
        # thief's fresh folds overwrite it within a batch), so drive the
        # adopt -> recovery attribution directly: an emission gated by a
        # checkpoint-adopted lane charges the steal delay to ``recovery``
        # and anchors at the stored checkpoint
        evs = [
            TraceEvent(t_ms=100.0, kind="ckpt.apply", node=0, partition=0,
                       status="applied", args=mkargs(wm=(5,), nxt_idx=5)),
            TraceEvent(t_ms=200.0, kind="steal.adopt", node=1, partition=0,
                       status="ckpt"),
            TraceEvent(t_ms=250.0, kind="emit", node=1, partition=0, window=0,
                       status="accepted",
                       args=mkargs(digest=1, latency_ms=300.0)),
        ]
        (p,) = analyze(evs).paths
        assert p.phases["recovery"] == pytest.approx(100.0)  # adopt - ckpt
        assert p.phases["queue"] == pytest.approx(50.0)  # emit - adopt
        assert p.path_ms == pytest.approx(150.0) and p.hops == 1
        assert p.origin == 0  # the checkpoint writer, not the thief

    @pytest.mark.parametrize("name", ["baseline", "concurrent"])
    def test_flink_path_invariants(self, name):
        h = _run(scenario=SCENARIOS[name], harness_cls=FlinkHarness)
        report = analyze_harness(h)
        assert report.system == "flink" and report.topology == "tree"
        _check_properties(report, _accepted(h))
        # the static agg tree always pays shuffle hops: wire mass is real
        assert sum(p.phases["wire"] for p in report.paths) > 0.0


# ---------------------------------------------------------------------------
# phase coverage under chaos and across topologies
# ---------------------------------------------------------------------------
class TestPhaseCoverage:
    def test_lossy_links_show_wire_and_loss_stall(self):
        cfg = dataclasses.replace(CHAOS_CFG, net_loss=0.40)
        h = _run(cfg, None)
        report = analyze_harness(h)
        _check_properties(report, _accepted(h))
        assert sum(p.phases["wire"] for p in report.paths) > 0.0
        assert sum(p.phases["loss_stall"] for p in report.paths) > 0.0

    @pytest.mark.parametrize("topo", ["ring:2", "hypercube"])
    def test_sparse_topologies_analyzed(self, topo):
        cfg = dataclasses.replace(CFG, topology=topo)
        h = _run(cfg, None)
        report = analyze_harness(h)
        assert report.topology == topo
        _check_properties(report, _accepted(h))

    def test_sparse_topology_stretches_hops(self):
        # on a ring, progress from a far node relays through intermediates:
        # max hop count is at least the all-to-all one
        paths_all = analyze_harness(_run(CFG, None)).paths
        ring = dataclasses.replace(CFG, topology="ring:1")
        paths_ring = analyze_harness(_run(ring, None)).paths
        assert max(p.hops for p in paths_ring) >= max(p.hops for p in paths_all)


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------
class TestDeterminism:
    @pytest.mark.parametrize("harness_cls", [HolonHarness, FlinkHarness])
    def test_same_seed_byte_identical_report(self, harness_cls):
        r1 = analyze_harness(_run(CHAOS_CFG, CHAOS_SCEN, harness_cls))
        r2 = analyze_harness(_run(CHAOS_CFG, CHAOS_SCEN, harness_cls))
        assert r1.to_json() == r2.to_json()
        assert len(r1.paths) > 0

    def test_report_json_schema(self):
        report = analyze_harness(_run())
        doc = json.loads(report.to_json())
        assert doc["meta"] == "holon-critpath-v1"
        assert doc["system"] == "holon"
        assert set(doc["summary"]["phase_ms"]) == set(PHASES)
        for p in doc["paths"]:
            assert set(p["phases"]) == set(PHASES)

    def test_analyze_accepts_plain_event_list(self):
        h = _run()
        via_list = analyze(list(h.obs.buf.events()), cfg=h.cfg)
        assert via_list.to_json() == analyze_harness(h).to_json()
