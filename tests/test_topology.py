"""Dissemination-topology tests (runtime/topology.py, docs/protocol.md §5).

Three layers:
* laws — pure schedule properties, no simulator: targets are valid peers
  (no self, no duplicates, drawn from the input), the union of consecutive
  rounds spans the whole membership, ring/hypercube schedules are
  permutation-fair (per-round in-degree == out-degree), sampling is
  deterministic, and the all-to-all oracle preserves input order (the
  byte-identity contract with the pre-topology event schedule);
* convergence — every sparse topology's window outputs are byte-identical
  to the ``AllToAll`` oracle under crash/restart, partition/heal, and
  scale_out/in Scenarios, at a fraction of the sync messages, and its
  obs-on runs pass the protocol auditor (multi-hop merges still ack their
  direct sender, so ``[unacked-merge]`` holds unchanged);
* chaos (``-m chaos``, excluded from tier-1) — the 64-node convergence
  sweep and the 256-node schedule-law checks behind the slow marker.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np
import pytest

from repro.obs.audit import audit_harness
from repro.runtime import (
    AllToAll,
    EpochRing,
    HolonHarness,
    Hypercube,
    PartialView,
    Scenario,
    SimConfig,
    run_holon,
    topology_from_spec,
)
from repro.streaming import make_q7

# ---------------------------------------------------------------------------
# laws: pure schedule properties
# ---------------------------------------------------------------------------

SPECS = ("all", "ring:1", "ring:2", "ring:3", "hypercube", "partial:1",
         "partial:3")
# membership sets deliberately non-contiguous and unsorted: schedules must
# key off ids, not positions in some assumed 0..N-1 range
MEMBERSHIPS = (
    [0, 1],
    [3, 7, 9],
    [5, 0, 2, 8, 11],
    list(range(8)),
    [17, 4, 23, 9, 31, 0, 12, 8, 40, 2, 19, 27, 33],
    list(range(32)),
)


def _peers(members, nid):
    return [m for m in members if m != nid]


@pytest.mark.parametrize("spec", SPECS)
@pytest.mark.parametrize("members", MEMBERSHIPS, ids=lambda m: f"n{len(m)}")
def test_targets_are_valid_peers(spec, members):
    topo = topology_from_spec(spec, seed=3)
    for nid in members:
        peers = _peers(members, nid)
        for rnd in range(3 * len(members)):
            out = topo.peers_of(nid, rnd, peers)
            assert nid not in out
            assert len(out) == len(set(out))
            assert set(out) <= set(peers)


@pytest.mark.parametrize("spec", SPECS)
@pytest.mark.parametrize("members", MEMBERSHIPS, ids=lambda m: f"n{len(m)}")
def test_union_of_rounds_spans_live_set(spec, members):
    """Eventual dissemination needs every node's state to reach every
    other: the union graph of enough consecutive rounds — from any
    starting round — must make the whole live set mutually reachable
    (multi-hop relay carries what direct edges do not; the hypercube on a
    non-power-of-two membership is the honest case here)."""
    topo = topology_from_spec(spec, seed=3)
    horizon = 4 * len(members) + 8
    for start in (0, 5):
        edges: dict[int, set[int]] = {m: set() for m in members}
        for rnd in range(start, start + horizon):
            for nid in members:
                edges[nid] |= set(topo.peers_of(nid, rnd, _peers(members, nid)))
        for nid in members:
            seen, frontier = {nid}, [nid]
            while frontier:
                nxt = edges[frontier.pop()] - seen
                seen |= nxt
                frontier.extend(nxt)
            assert seen == set(members), (
                f"{spec}: state of {nid} can never reach "
                f"{set(members) - seen}"
            )


@pytest.mark.parametrize("spec", ("all", "ring:1", "ring:2", "ring:3",
                                  "partial:3"))
@pytest.mark.parametrize("members", MEMBERSHIPS, ids=lambda m: f"n{len(m)}")
def test_direct_union_spans_peers(spec, members):
    """Ring rotation and repeated sampling (and trivially all-to-all)
    additionally contact every peer *directly* given enough rounds — the
    property that lets ack baselines keep advancing for every peer.
    (``partial:1`` is exempt: a fanout-1 sampler's direct coverage is a
    coupon-collector tail; reachability above is its real contract.)"""
    topo = topology_from_spec(spec, seed=3)
    horizon = 6 * len(members) + 30
    for nid in members:
        peers = set(_peers(members, nid))
        union: set = set()
        for rnd in range(horizon):
            union |= set(topo.peers_of(nid, rnd, sorted(peers)))
            if union == peers:
                break
        assert union == peers, (
            f"{spec}: node {nid} never contacts {peers - union}"
        )


@pytest.mark.parametrize("members", MEMBERSHIPS, ids=lambda m: f"n{len(m)}")
@pytest.mark.parametrize("k", (1, 2, 3))
def test_ring_is_permutation_fair(members, k):
    """Every round of EpochRing(k) is a k-regular exchange: each node
    contacts exactly k distinct peers (capped by N-1) and is contacted by
    exactly as many — no node is a hotspot in any round."""
    topo = EpochRing(k)
    deg = min(k, len(members) - 1)
    for rnd in range(2 * len(members)):
        indeg = {m: 0 for m in members}
        for nid in members:
            out = topo.peers_of(nid, rnd, _peers(members, nid))
            assert len(out) == deg
            for t in out:
                indeg[t] += 1
        assert set(indeg.values()) == {deg}


@pytest.mark.parametrize("members", MEMBERSHIPS, ids=lambda m: f"n{len(m)}")
def test_hypercube_pairing_is_symmetric(members):
    """Hypercube rounds are matchings: a contacts b iff b contacts a, so
    in-degree equals out-degree (<= 1) for every node in every round."""
    topo = Hypercube()
    dim = max(1, (len(members) - 1).bit_length())
    for rnd in range(2 * dim):
        for nid in members:
            out = topo.peers_of(nid, rnd, _peers(members, nid))
            assert len(out) <= 1
            for t in out:
                assert topo.peers_of(t, rnd, _peers(members, t)) == [nid]


def test_partial_view_is_seeded_and_deterministic():
    members = list(range(24))
    a = PartialView(fanout=4, seed=9)
    b = PartialView(fanout=4, seed=9)
    c = PartialView(fanout=4, seed=10)
    rounds = [
        tuple(a.peers_of(5, r, _peers(members, 5))) for r in range(40)
    ]
    assert rounds == [
        tuple(b.peers_of(5, r, _peers(members, 5))) for r in range(40)
    ]
    assert rounds != [
        tuple(c.peers_of(5, r, _peers(members, 5))) for r in range(40)
    ], "different seeds should sample different schedules"
    assert all(len(r) == 4 for r in rounds)
    # different rounds actually vary the sample (not a frozen view)
    assert len(set(rounds)) > 1


def test_all_to_all_preserves_input_order():
    """The oracle must return the peer list unmodified — same ids, same
    order — so a default run schedules bit-for-bit the pre-topology event
    sequence."""
    peers = [9, 2, 14, 0, 7]
    assert AllToAll().peers_of(3, 0, peers) == peers
    assert AllToAll().peers_of(3, 17, peers) == peers


def test_from_spec_parses_and_rejects():
    assert isinstance(topology_from_spec("all"), AllToAll)
    assert topology_from_spec("ring").k == 2
    assert topology_from_spec("ring:5").k == 5
    assert isinstance(topology_from_spec("hypercube"), Hypercube)
    assert topology_from_spec("partial").fanout == 3
    assert topology_from_spec("partial:7", seed=2).seed == 2
    for bad in ("mesh", "ring:0", "partial:0", "all:3", "hypercube:2", ""):
        with pytest.raises(ValueError):
            topology_from_spec(bad)


# ---------------------------------------------------------------------------
# convergence: byte-identical to the all-to-all oracle under churn
# ---------------------------------------------------------------------------

SMALL = SimConfig(
    num_nodes=5,
    num_partitions=10,
    num_batches=40,
    events_per_batch=256,
    rate_per_partition=5_000.0,
    window_len=500,
    num_slots=32,
    ckpt_interval_ms=400.0,
    sync_interval_ms=50.0,
)

SPARSE = ("ring:2", "hypercube", "partial:2")

SCENARIOS = {
    "crash_restart": Scenario("cr").crash(1000, 1).restart(2400, 1),
    "partition_heal": Scenario("ph")
    .partition(800, (0, 1), (2, 3, 4))
    .heal(2200),
    "scale_out_in": Scenario("oi").scale_out(900, 5, 6).scale_in(2800, 5, 6),
}


def _values(consumer):
    return {k: np.asarray(r.value).tobytes() for k, r in consumer.records.items()}


@pytest.fixture(scope="module")
def q7():
    return make_q7(SMALL.num_partitions, window_len=SMALL.window_len,
                   num_slots=SMALL.num_slots)


@pytest.fixture(scope="module")
def oracles(q7):
    return {
        name: run_holon(SMALL, q7, sc) for name, sc in SCENARIOS.items()
    }


@pytest.mark.parametrize("spec", SPARSE)
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_sparse_topology_outputs_match_oracle(spec, scenario, q7, oracles):
    """Window outputs under a sparse dissemination graph are byte-identical
    to the all-to-all oracle through every churn family — merge is a
    lattice join, so the route (and its loss of direct contact) costs only
    propagation hops, never values (docs/protocol.md §5)."""
    oracle = oracles[scenario]
    c = run_holon(dataclasses.replace(SMALL, topology=spec), q7,
                  SCENARIOS[scenario])
    assert _values(c) == _values(oracle)
    # sparse rounds genuinely contact fewer peers than the oracle's O(N^2)
    assert c.sync_msgs < oracle.sync_msgs


@pytest.mark.parametrize("spec", SPARSE)
def test_sparse_topology_run_passes_audit(spec, q7):
    """The trace auditor's invariants — including [unacked-merge], which
    cross-checks every merge against a fabric-recorded ack to the *direct*
    sender — hold under multi-hop dissemination: relay changes who you
    merge from, not the ack contract."""
    cfg = dataclasses.replace(SMALL, topology=spec, obs=True)
    h = HolonHarness(cfg, q7)
    h.run(Scenario("mix").crash(1000, 1).restart(2200, 1)
          .scale_out(1400, 5).scale_in(3000, 5))
    rep = audit_harness(h)
    assert rep.ok, rep.violations
    pubs = [e for e in h.obs.events() if e.kind == "sync.publish"]
    assert pubs and all(e.arg("topology") == spec for e in pubs)
    assert all(e.arg("fanout") == len(e.arg("peers")) for e in pubs)


def test_counterfactual_excludes_bootstrap_bytes(q7):
    """``sync_bytes_full`` models periodic full-state all-to-all rounds
    only — never joiner bootstraps (those are real, fabric-metered
    traffic, not part of the counterfactual).  Sharp check: with
    ``delta_sync=False`` every periodic round *actually* ships the
    counterfactual, so real sync bytes exceed ``sync_bytes_full`` by
    exactly the bootstrap replies."""
    cfg = dataclasses.replace(SMALL, delta_sync=False)
    h = HolonHarness(cfg, q7)
    c = h.run(Scenario("join").scale_out(900, 5, 6).scale_in(2800, 5, 6))
    served = len(h.bootstrap_served)
    assert served >= 2
    assert c.sync_bytes == c.sync_bytes_full + served * h.full_state_bytes


def test_peer_cache_tracks_membership_churn(q7):
    """The subscription-versioned peer cache must observe every
    subscribe/unsubscribe transition: after a drain the drained node stops
    appearing in anyone's peer list, and after a revival it reappears."""
    h = HolonHarness(SMALL, q7)
    h.run(Scenario("churn").scale_out(900, 5).scale_in(2400, 5))
    n0 = h.nodes[0]
    assert 5 in h.unsubscribed
    assert all(p.nid != 5 for p in n0._peers())
    ver = h._sub_version
    h._subscribe(5)
    assert h._sub_version == ver + 1
    assert any(p.nid == 5 for p in n0._peers())


def test_baseline_ttl_ages_out_to_full_state(q7):
    """With ``baseline_ttl_ms`` set, a baseline not refreshed by an ack
    within the window is dropped and the next round ships relative to
    ``zero_base`` — more bytes, same values."""
    cfg = dataclasses.replace(SMALL, topology="ring:1",
                              baseline_ttl_ms=150.0)
    oracle = run_holon(dataclasses.replace(SMALL, topology="ring:1"), q7)
    aged = run_holon(cfg, q7)
    assert _values(aged) == _values(oracle)
    # aged-out baselines force periodic full-state rounds: strictly more
    # sync bytes than the never-aging run
    assert aged.sync_bytes > oracle.sync_bytes


# ---------------------------------------------------------------------------
# chaos sweeps (slow; scripts/test.sh chaos)
# ---------------------------------------------------------------------------


@pytest.mark.chaos
@pytest.mark.parametrize("spec", SPARSE)
def test_chaos_64_node_convergence_matches_oracle(spec):
    cfg = SimConfig(
        num_nodes=64,
        num_partitions=64,
        num_batches=16,
        events_per_batch=128,
        rate_per_partition=1_000.0,
        window_len=512,
        num_slots=32,
        sync_interval_ms=100.0,
        ckpt_interval_ms=1000.0,
        hb_timeout_ms=4000.0,  # sparse liveness floods in O(log N) beacons
    )
    q = make_q7(cfg.num_partitions, window_len=cfg.window_len,
                num_slots=cfg.num_slots)
    oracle = run_holon(cfg, q)
    c = run_holon(dataclasses.replace(cfg, topology=spec), q)
    assert _values(c) == _values(oracle)
    assert c.sync_msgs < oracle.sync_msgs / 4


@pytest.mark.chaos
@pytest.mark.parametrize("spec", ("ring:2", "ring:4", "hypercube",
                                  "partial:3", "partial:5"))
def test_chaos_256_node_schedule_laws(spec):
    """Schedule laws at the ROADMAP's target scale (pure, no simulator):
    coverage and degree bounds must hold at N=256 too."""
    members = list(range(256))
    topo = topology_from_spec(spec, seed=1)
    fan = {"ring:2": 2, "ring:4": 4, "hypercube": 1, "partial:3": 3,
           "partial:5": 5}[spec]
    # multi-hop spanning: BFS over the union edge graph of a bounded round
    # window reaches every member — direct contact is NOT the contract
    # (hypercube only ever touches its log2 N partners, and partial:f's
    # direct coupon-collector tail needs ~N ln N / f rounds)
    edges: dict[int, set] = {n: set() for n in members}
    for rnd in range(64):
        out = topo.peers_of(77, rnd, _peers(members, 77))
        assert len(out) <= fan
        for n in members:
            edges[n] |= set(topo.peers_of(n, rnd, _peers(members, n)))
    seen, frontier = {77}, {77}
    while frontier:
        nxt = set().union(*(edges[n] for n in frontier)) - seen
        seen |= nxt
        frontier = nxt
    assert seen == set(members)
    # per-round message budget is fanout * N — sub-quadratic by construction
    total = sum(
        len(topo.peers_of(n, 3, _peers(members, n))) for n in members
    )
    assert total <= fan * 256
    assert total < 256 * 255 / 4
