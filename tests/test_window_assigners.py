"""Window-assigner laws (DESIGN.md §8).

* ``Tumbling`` reproduces the pre-assigner integer division exactly;
* every (interior) event lands in exactly ``window_len // hop`` hopping
  windows, and ``assign``/``contains``/``first_dirty_wid`` agree;
* a complete window can never receive a later fold — completion is final;
* the evicted-window read path: ``window_value`` ok=False plus
  ``ERR_EVICT_INCOMPLETE`` / ``ERR_RING`` accounting, tumbling and
  overlapping alike.
"""
import jax.numpy as jnp
import numpy as np
from _prop import given, settings, st

from repro.core import wcrdt as W
from repro.core import wgcounter
from repro.core.window import Hopping, Tumbling, as_assigner, expand_events

settings.register_profile("ci-assigners", max_examples=40, deadline=None)
settings.load_profile("ci-assigners")


# ---------------------------------------------------------------------------
# Assigner laws
# ---------------------------------------------------------------------------


@given(window_len=st.integers(1, 500), ts=st.lists(st.integers(0, 10_000),
                                                   min_size=1, max_size=32))
def test_tumbling_matches_integer_division(window_len, ts):
    """Tumbling.assign is exactly the old ``ts // window_len`` rule: K == 1,
    every lane valid, and the wid equals the division."""
    a = Tumbling(window_len)
    t = jnp.array(ts, jnp.int32)
    wids, valid = a.assign(t)
    assert a.windows_per_event == 1 and wids.shape == (len(ts), 1)
    np.testing.assert_array_equal(np.asarray(wids[:, 0]), np.array(ts) // window_len)
    assert bool(valid.all())
    np.testing.assert_array_equal(np.asarray(a.window_of(t)), np.array(ts) // window_len)
    for x in ts:
        assert a.end_ts(x // window_len) == (x // window_len + 1) * window_len
    assert a == as_assigner(window_len) and a == as_assigner(window_len, window_len)


@given(hop=st.integers(1, 50), k=st.integers(1, 8),
       ts=st.lists(st.integers(0, 5_000), min_size=1, max_size=32))
def test_hopping_event_lands_in_exactly_k_windows(hop, k, ts):
    """An event at ``ts`` belongs to exactly ``min(K, ts // hop + 1)`` valid
    windows (K for every interior event), and each claimed window actually
    contains it while no unclaimed one does."""
    a = Hopping(hop * k, hop)
    assert a.windows_per_event == k
    t = jnp.array(ts, jnp.int32)
    wids, valid = a.assign(t)
    n_valid = np.asarray(valid.sum(axis=-1))
    np.testing.assert_array_equal(
        n_valid, np.minimum(k, np.array(ts) // hop + 1)
    )
    wids_np, valid_np = np.asarray(wids), np.asarray(valid)
    for i, x in enumerate(ts):
        claimed = set(wids_np[i][valid_np[i]].tolist())
        assert claimed == {w for w in range(x // hop + 1) if bool(a.contains(w, x))}
        for w in claimed:
            assert a.start_ts(w) <= x < a.end_ts(w)


@given(hop=st.integers(1, 50), k=st.integers(1, 8), gwm=st.integers(0, 5_000),
       ts=st.integers(0, 5_000))
def test_complete_window_never_receives_a_later_fold(hop, k, gwm, ts):
    """Completion is final: once ``complete(wid, gwm)``, no event at
    ``ts >= gwm`` (the only events a watermark-respecting fold can still
    see) is ever assigned to ``wid``."""
    a = Hopping(hop * k, hop)
    ts = max(ts, gwm)  # events below the watermark are late-dropped
    wids, valid = a.assign(jnp.int32(ts))
    assigned = set(np.asarray(wids)[np.asarray(valid)].tolist())
    for wid in assigned:
        assert not a.complete(wid, gwm), (wid, gwm, ts)
    # contrapositive via first_dirty_wid: every assigned wid is at/after it
    assert all(w >= a.first_dirty_wid(gwm) for w in assigned)


@given(hop=st.integers(1, 50), k=st.integers(1, 8), frontier=st.integers(0, 5_000))
def test_first_dirty_wid_is_tight(hop, k, frontier):
    """``first_dirty_wid(F)`` is the exact minimum of the windows reachable
    by events at ts >= F: the window it names contains F, and no smaller
    window contains any ts >= F."""
    a = Hopping(hop * k, hop)
    w0 = a.first_dirty_wid(frontier)
    assert bool(a.contains(w0, frontier)) or (frontier < a.start_ts(w0) == 0)
    if w0 > 0:
        assert a.end_ts(w0 - 1) <= frontier  # smaller windows already closed
    # tumbling degenerate equals the original delta dirty rule
    t = Tumbling(hop * k)
    assert t.first_dirty_wid(frontier) == frontier // (hop * k)


@given(hop=st.integers(1, 40), k=st.integers(2, 6), seed=st.integers(0, 2**20))
def test_hopping_insert_counts_match_oracle(hop, k, seed):
    """Multi-window insert: a windowed GCounter under Hopping counts every
    event once per containing window — matching a direct per-window count."""
    rng = np.random.default_rng(seed)
    a = Hopping(hop * k, hop)
    n = int(rng.integers(4, 24))
    ts = np.sort(rng.integers(0, hop * k * 3, size=n)).astype(np.int32)
    spec = wgcounter(hop * k, num_slots=4 * k + 8, num_partitions=1, assigner=a)
    s = spec.zero()
    s = W.insert(spec, s, 0, jnp.array(ts), jnp.ones(n, bool),
                 actor=0, amounts=jnp.ones(n))
    s = W.increment_watermark(spec, s, 0, int(ts.max()) + hop * k)
    for wid in range(int(ts.max()) // hop + 1):
        v, ok = W.window_value(spec, s, wid)
        assert bool(ok)
        want = int(((ts >= wid * hop) & (ts < wid * hop + hop * k)).sum())
        assert float(v) == want, (wid, float(v), want)


def test_expand_events_lane_layout():
    """expand_events flattens [B] events into [B*K] newest-first lanes with
    out-of-range (pre-t=0) windows masked — the layout _expand_payload's
    jnp.repeat must match."""
    a = Hopping(10, 5)
    ts = jnp.array([3, 12], jnp.int32)
    wid, mask = expand_events(a, ts, jnp.array([True, True]))
    np.testing.assert_array_equal(np.asarray(wid), [0, -1, 2, 1])
    np.testing.assert_array_equal(np.asarray(mask), [True, False, True, True])
    # a masked-out event contributes no lanes at all
    _, mask2 = expand_events(a, ts, jnp.array([True, False]))
    np.testing.assert_array_equal(np.asarray(mask2), [True, False, False, False])


# ---------------------------------------------------------------------------
# Evicted-window read path (ok=False + error accounting)
# ---------------------------------------------------------------------------


def _drive_overflow(assigner, num_slots):
    """Fold one event per window id far past the ring size, without ever
    advancing the watermark — every slot reuse evicts an incomplete window."""
    spec = wgcounter(assigner.window_len, num_slots, 1, assigner=assigner)
    s = spec.zero()
    n_windows = num_slots * 3
    last_start = (n_windows - 1) * assigner.hop
    for start in range(0, last_start + 1, assigner.hop):
        t = jnp.array([start], jnp.int32)
        s = W.insert(spec, s, 0, t, jnp.ones(1, bool), actor=0, amounts=jnp.ones(1))
    return spec, s, n_windows


def test_evicted_incomplete_window_accounting_tumbling():
    spec, s, n_windows = _drive_overflow(Tumbling(10), num_slots=2)
    # every slot reuse beyond the first ring fill evicted an incomplete window
    assert int(s.errors[W.ERR_EVICT_INCOMPLETE]) == n_windows - 2
    # completed-by-now early windows read ok=False: evicted before complete
    s = W.increment_watermark(spec, s, 0, 10 * n_windows)
    for wid in (0, 1, n_windows - 3):
        _, ok = W.window_value(spec, s, wid)
        assert not bool(ok), wid
    v, ok = W.window_value(spec, s, n_windows - 1)
    assert bool(ok) and float(v) == 1.0


def test_evicted_incomplete_window_accounting_hopping():
    """Same invariant under overlap: slot reuse before completion is counted,
    evicted windows read not-ok, resident complete windows still read."""
    a = Hopping(20, 5)  # K=4 concurrent windows per event
    spec, s, n_windows = _drive_overflow(a, num_slots=8)
    assert int(s.errors[W.ERR_EVICT_INCOMPLETE]) > 0
    s = W.increment_watermark(spec, s, 0, a.end_ts(n_windows))
    evicted = [w for w in range(n_windows)
               if int(s.slot_wid[w % spec.num_slots]) > w]
    assert evicted, "overflow must have evicted windows"
    for wid in evicted:
        _, ok = W.window_value(spec, s, wid)
        assert not bool(ok), wid
    # the newest windows are resident and complete; each saw K events
    # (one per hop) except near the stream tail
    wid = n_windows - a.windows_per_event
    v, ok = W.window_value(spec, s, wid)
    assert bool(ok) and float(v) == a.windows_per_event


def test_late_events_still_counted_per_event_under_overlap():
    """ERR_LATE counts events (not per-window copies) under a K>1 assigner."""
    a = Hopping(10, 5)
    spec = wgcounter(10, 8, 1, assigner=a)
    s = spec.zero()
    s = W.increment_watermark(spec, s, 0, 25)
    ts = jnp.array([5, 30], jnp.int32)  # 5 is behind the watermark
    s = W.insert(spec, s, 0, ts, jnp.ones(2, bool), actor=0, amounts=jnp.ones(2))
    assert int(s.errors[W.ERR_LATE]) == 1
    # the late event folded into no window; 30 folded into windows 5 and 6
    s = W.increment_watermark(spec, s, 0, 100)
    for wid, want in ((0, 0.0), (1, 0.0), (5, 1.0), (6, 1.0)):
        v, ok = W.window_value(spec, s, wid)
        assert bool(ok) and float(v) == want, (wid, float(v))


def test_ring_drop_counts_per_window_assignment():
    """ERR_RING counts dropped (event, window) assignments: an event whose
    older overlapping window was already evicted still folds into its newer
    windows, and only the stale lane is counted."""
    a = Hopping(10, 5)
    spec = wgcounter(10, 4, 1, assigner=a)
    s = spec.zero()
    # fill the ring far ahead: windows 10 and 11 occupy slots 2 and 3
    s = W.insert(spec, s, 0, jnp.array([55], jnp.int32), jnp.ones(1, bool),
                 actor=0, amounts=jnp.ones(1))
    # ts=47 -> windows 9 (slot 1) and 8 (slot 0): both fold fine; but ts=43
    # -> windows 8 (ok) and 7 (slot 3, evicted by tenant 11) -> 1 ring drop
    before = int(s.errors[W.ERR_RING])
    s = W.insert(spec, s, 0, jnp.array([43], jnp.int32), jnp.ones(1, bool),
                 actor=0, amounts=jnp.ones(1))
    assert int(s.errors[W.ERR_RING]) == before + 1
    s = W.increment_watermark(spec, s, 0, 200)
    v, ok = W.window_value(spec, s, 8)
    assert bool(ok) and float(v) == 1.0  # the newer lane still landed
