"""CheckpointStorage.put lattice-merge laws (Algorithm 2's "sometimes do").

Concurrent checkpointers of the same partition are allowed, so put must be a
join: the stored checkpoint's key ``(nxt_idx, coverage, epoch)`` (the exact
tie-break order implemented in storage.py) has to behave like a
join-semilattice — idempotent, commutative at the key level, and monotone
under any interleaving — or a slow checkpointer could regress recovery.
"""
from __future__ import annotations

import numpy as np
import pytest
from _prop import given, settings, st

from repro.runtime.storage import CheckpointStorage, PartitionCheckpoint, _coverage

settings.register_profile("ci-storage", max_examples=40, deadline=None)
settings.load_profile("ci-storage")


def mk_ckpt(nxt_idx: int, folded: list[int], epoch: int = 0) -> PartitionCheckpoint:
    """A checkpoint whose coverage is sum(folded) — local/shared payloads are
    opaque to the merge rule, so a tag is enough to tell objects apart."""
    baseline = (
        (np.asarray(folded, dtype=np.float64), np.zeros(len(folded))),
    )
    return PartitionCheckpoint(
        nxt_idx=nxt_idx,
        nxt_odx=nxt_idx,
        emitted_upto=nxt_idx,
        shared=("shared", nxt_idx, tuple(folded), epoch),
        local=None,
        baseline=baseline,
        epoch=epoch,
    )


def key(ck: PartitionCheckpoint) -> tuple:
    return (ck.nxt_idx, _coverage(ck), ck.epoch)


CKPT = st.tuples(
    st.integers(0, 5),  # nxt_idx — small range to force ties
    st.lists(st.integers(0, 3), min_size=2, max_size=2),  # folded -> coverage
    st.integers(0, 2),  # epoch
)


def put_all(cks):
    s = CheckpointStorage()
    for ck in cks:
        s.put(0, ck)
    return s


def test_none_baseline_has_zero_coverage():
    assert _coverage(PartitionCheckpoint(0, 0, 0, None, None)) == 0.0


@given(c=CKPT)
def test_put_idempotent(c):
    ck = mk_ckpt(*c)
    s = put_all([ck])
    first = s.get(0)
    s.put(0, ck)
    assert s.get(0) is first  # re-putting the same snapshot changes nothing


@given(a=CKPT, b=CKPT)
def test_put_commutative_on_key(a, b):
    """put(a);put(b) and put(b);put(a) must agree on the stored *key* — the
    recovery-relevant ordering — for every pair, including exact key ties
    (where either equal-keyed object is a legal representative)."""
    ka, kb = key(mk_ckpt(*a)), key(mk_ckpt(*b))
    sab = put_all([mk_ckpt(*a), mk_ckpt(*b)])
    sba = put_all([mk_ckpt(*b), mk_ckpt(*a)])
    assert key(sab.get(0)) == key(sba.get(0)) == max(ka, kb)


@given(cs=st.lists(CKPT, min_size=1, max_size=6))
def test_put_monotone(cs):
    """Under any put sequence the stored key is the running max and never
    regresses — a stale checkpointer cannot undo a fresher snapshot."""
    s = CheckpointStorage()
    best = None
    for c in cs:
        ck = mk_ckpt(*c)
        s.put(0, ck)
        best = key(ck) if best is None else max(best, key(ck))
        assert key(s.get(0)) == best


@given(cs=st.lists(CKPT, min_size=2, max_size=5))
def test_put_order_invariant_key(cs):
    """Full permutation-independence at the key level: left-to-right and
    right-to-left interleavings converge to the same stored key."""
    fwd = put_all([mk_ckpt(*c) for c in cs])
    rev = put_all([mk_ckpt(*c) for c in reversed(cs)])
    assert key(fwd.get(0)) == key(rev.get(0))


def test_tiebreak_order_is_nxt_idx_then_coverage_then_epoch():
    lo = mk_ckpt(1, [9, 9], epoch=9)
    hi = mk_ckpt(2, [0, 0], epoch=0)
    s = put_all([lo, hi])
    assert s.get(0) is s._data[0] and s.get(0).nxt_idx == 2  # idx dominates
    # equal idx: coverage dominates epoch
    rich = mk_ckpt(2, [3, 3], epoch=0)
    s.put(0, rich)
    assert _coverage(s.get(0)) == 6.0
    poor_new_epoch = mk_ckpt(2, [0, 0], epoch=5)
    s.put(0, poor_new_epoch)
    assert _coverage(s.get(0)) == 6.0  # newer epoch cannot beat richer coverage
    # equal (idx, coverage): epoch breaks the tie
    newer = mk_ckpt(2, [3, 3], epoch=7)
    s.put(0, newer)
    assert s.get(0).epoch == 7


def test_get_and_has_roundtrip():
    s = CheckpointStorage()
    assert s.get(3) is None and not s.has(3)
    ck = mk_ckpt(0, [0, 0])
    s.put(3, ck)
    assert s.has(3) and s.get(3) is ck
    assert s.puts == 1 and s.gets == 2
