# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see ONE
# CPU device.  The 512-device environment exists only inside
# repro.launch.dryrun (and the subprocess spawned by test_dryrun_mini).
import jax

jax.config.update("jax_platform_name", "cpu")
