"""Elastic reconfiguration (docs/protocol.md §3): rendezvous placement laws,
scale-out/scale-in exactly-once (including a drain landing mid-delta-round),
graceful-handoff cheapness, and membership-epoch plumbing."""
import dataclasses

import numpy as np
from _prop import given, settings, st

from repro.runtime import Scenario, SimConfig, assignment, run_holon
from repro.runtime.harness import HolonHarness
from repro.streaming import make_q1_ratio, make_q7

settings.register_profile("ci-reconfig", max_examples=25, deadline=None)
settings.load_profile("ci-reconfig")

CFG = SimConfig(
    num_nodes=3,
    num_partitions=8,
    num_batches=40,
    events_per_batch=256,
    window_len=500,
    num_slots=32,
    sync_interval_ms=50.0,
    ckpt_interval_ms=300.0,
)


def _vals(consumer):
    return {k: np.asarray(r.value) for k, r in consumer.records.items()}


def _check_byte_identical(oracle, got):
    missing = set(oracle) - set(got)
    assert not missing, f"lost outputs: {sorted(missing)[:5]}"
    for k in oracle:
        np.testing.assert_array_equal(got[k], oracle[k], err_msg=str(k))


# ---------------------------------------------------------------------------
# Rendezvous placement laws
# ---------------------------------------------------------------------------


def test_rendezvous_total_and_deterministic():
    members = [0, 3, 7, 11]
    for pid in range(64):
        owner = assignment(pid, members)
        assert owner in members
        # membership order must not matter (peers sort their live views, but
        # the rule itself is order-free)
        assert assignment(pid, list(reversed(members))) == owner
    assert assignment(0, []) == -1


@given(
    members=st.lists(st.integers(0, 40), min_size=1, max_size=10, unique=True),
    joiner=st.integers(0, 40),
)
def test_rendezvous_join_moves_only_to_joiner(members, joiner):
    """Adding a node never moves a partition between two old nodes."""
    grown = sorted(set(members) | {joiner})
    for pid in range(32):
        before = assignment(pid, members)
        after = assignment(pid, grown)
        assert after == before or after == joiner


@given(
    members=st.lists(st.integers(0, 40), min_size=2, max_size=10, unique=True),
    victim_idx=st.integers(0, 9),
)
def test_rendezvous_leave_moves_only_victims_partitions(members, victim_idx):
    """Removing a node only reassigns the partitions it owned."""
    victim = sorted(members)[victim_idx % len(members)]
    shrunk = [n for n in members if n != victim]
    for pid in range(32):
        before = assignment(pid, members)
        if before != victim:
            assert assignment(pid, shrunk) == before


@given(seed=st.integers(0, 2**20))
def test_rendezvous_stable_under_churn(seed):
    """Along any churn path, a partition moves only at a step whose change
    explains the move: its current owner left, or the mover is the joiner."""
    import random

    rng = random.Random(seed)
    members = set(range(4))
    owners = {p: assignment(p, sorted(members)) for p in range(32)}
    for _ in range(rng.randint(1, 8)):
        gone = joined = None
        if rng.random() < 0.5 and len(members) > 1:
            gone = rng.choice(sorted(members))
            members.discard(gone)
        else:
            joined = rng.randint(0, 12)
            if joined in members:
                joined = None  # no-op add: nothing may move
            else:
                members.add(joined)
        for p in range(32):
            new = assignment(p, sorted(members))
            if new != owners[p]:
                assert owners[p] == gone or new == joined, (
                    f"p{p} moved {owners[p]}->{new} on gone={gone} joined={joined}"
                )
            owners[p] = new


# ---------------------------------------------------------------------------
# Elastic runs: byte-identical to the fixed-membership oracle
# ---------------------------------------------------------------------------


def test_scale_out_exactly_once():
    q = make_q7(CFG.num_partitions, window_len=CFG.window_len, num_slots=CFG.num_slots)
    oracle = _vals(run_holon(CFG, q))
    assert len(oracle) > 0
    got = _vals(run_holon(CFG, q, Scenario("out").scale_out(1200.0, 3, 4)))
    _check_byte_identical(oracle, got)


def test_scale_in_mid_delta_round_exactly_once():
    """Drain a node while its previous sync round's deltas are still in
    flight (sync publishes land at k*sync_interval, deliveries at +5 ms;
    draining at +2 ms puts the departure between publish and delivery) —
    outputs must stay byte-identical to the static-membership oracle."""
    q = make_q7(CFG.num_partitions, window_len=CFG.window_len, num_slots=CFG.num_slots)
    oracle = _vals(run_holon(CFG, q))
    mid_flight = 16 * CFG.sync_interval_ms + 2.0
    for victim in (0, 2):
        got = _vals(run_holon(CFG, q, Scenario("drain").scale_in(mid_flight, victim)))
        _check_byte_identical(oracle, got)
        assert set(got) == set(oracle)


def test_scale_in_then_out_rejoin_q1_ratio():
    """Drain then re-add the same node (local+shared state query): the
    rejoin rides the restart path and outputs match the oracle."""
    q = make_q1_ratio(
        CFG.num_partitions, window_len=CFG.window_len, num_slots=CFG.num_slots
    )
    oracle = _vals(run_holon(CFG, q))
    scen = Scenario("inout").scale_in(700.0, 1).scale_out(1600.0, 1)
    got = _vals(run_holon(CFG, q, scen))
    _check_byte_identical(oracle, got)


def test_double_resize_exactly_once():
    """3→5→3 round trip with a crash thrown in: still byte-identical."""
    q = make_q7(CFG.num_partitions, window_len=CFG.window_len, num_slots=CFG.num_slots)
    oracle = _vals(run_holon(CFG, q))
    scen = (
        Scenario("mix")
        .scale_out(600.0, 3, 4)
        .crash(1000.0, 0)
        .restart(1500.0, 0)
        .scale_in(1700.0, 3, 4)
    )
    got = _vals(run_holon(CFG, q, scen))
    _check_byte_identical(oracle, got)


# ---------------------------------------------------------------------------
# Drain handoff mechanics
# ---------------------------------------------------------------------------


def test_drain_hands_off_without_replay():
    """Graceful drain writes handoff checkpoints at the current frontier, so
    the takeover resumes from nxt_idx — the drained node's partitions see no
    duplicate emissions (replay would produce deduplicated duplicates)."""
    q = make_q7(CFG.num_partitions, window_len=CFG.window_len, num_slots=CFG.num_slots)
    h = HolonHarness(CFG, q)
    c = h.run(Scenario("drain").scale_in(1000.0, 1))
    assert all(r.duplicates == 0 for r in c.records.values()), "handoff replayed"
    # the drained node is gone from every live view and owns nothing
    n1 = h.nodes[1]
    assert not n1.alive and n1.departing and not n1.owned
    for nid in (0, 2):
        assert 1 not in h.nodes[nid]._live_view()


def test_join_bootstraps_full_state_from_peer():
    """A joiner requests a full-state sync from the first peer it hears; by
    run end it holds a converged replica and owns its rendezvous share."""
    q = make_q7(CFG.num_partitions, window_len=CFG.window_len, num_slots=CFG.num_slots)
    h = HolonHarness(CFG, q)
    h.run(Scenario("join").scale_out(1000.0, 7))
    joiner = h.nodes[7]
    assert joiner.alive and not joiner._bootstrap_pending
    expect = [
        p
        for p in range(CFG.num_partitions)
        if assignment(p, sorted(n.nid for n in h.nodes.values())) == 7
    ]
    assert joiner.owned == expect
    # replica converged with a veteran's (same folded frontier per spec)
    for a, b in zip(joiner.replica, h.nodes[0].replica):
        np.testing.assert_array_equal(np.asarray(a.folded), np.asarray(b.folded))


def test_multi_join_bootstraps_from_settled_peers_only():
    """In a multi-node scale-out, every joiner's §3.1 bootstrap handshake
    must be served by a settled node, never by an empty co-joiner (whose
    beacons carry joining=true)."""
    q = make_q7(CFG.num_partitions, window_len=CFG.window_len, num_slots=CFG.num_slots)
    h = HolonHarness(CFG, q)
    h.run(Scenario("multi").scale_out(1222.0, 3, 4, 5))
    served = dict(h.bootstrap_served)  # requester -> server
    assert set(served) == {3, 4, 5}, served
    assert all(server in (0, 1, 2) for server in served.values()), served


def test_decommission_crashed_node():
    """reconfigure(remove=...) of an already-crashed node closes its
    broadcast subscription (publishers stop paying for it) and the bumped
    epoch still reaches the live nodes."""
    q = make_q7(CFG.num_partitions, window_len=CFG.window_len, num_slots=CFG.num_slots)
    h = HolonHarness(CFG, q)
    c = h.run(Scenario("decomm").crash(1000.0, 1).scale_in(2000.0, 1))
    assert 1 in h.unsubscribed
    assert h.membership_epoch == 1
    for nid in (0, 2):
        assert h.nodes[nid].epoch == 1
        assert h.nodes[1] not in h.nodes[nid]._peers()
    # outputs unharmed (crash recovery already property-tested elsewhere)
    oracle = _vals(run_holon(CFG, q))
    _check_byte_identical(oracle, _vals(c))


def test_membership_epoch_reaches_checkpoints():
    """reconfigure bumps the epoch; it gossips through beacons and lands in
    the snapshot markers of every node's later checkpoints."""
    q = make_q7(CFG.num_partitions, window_len=CFG.window_len, num_slots=CFG.num_slots)
    h = HolonHarness(CFG, q)
    h.run(Scenario("epoch").scale_out(800.0, 3).scale_in(1500.0, 3))
    assert h.membership_epoch == 2
    epochs = [h.storage.get(p).epoch for p in range(CFG.num_partitions) if h.storage.has(p)]
    assert epochs and max(epochs) == 2
    # every surviving node gossiped up to the final epoch
    for nid in (0, 1, 2):
        assert h.nodes[nid].epoch == 2


def test_skewed_load_elastic_exactly_once():
    """Zipf-skewed partition load (generator pads cold partitions with
    invalid events): elasticity still byte-identical to the skewed oracle."""
    cfg = dataclasses.replace(CFG, skew=0.8)
    q = make_q7(cfg.num_partitions, window_len=cfg.window_len, num_slots=cfg.num_slots)
    oracle = _vals(run_holon(cfg, q))
    assert len(oracle) > 0
    scen = Scenario("skewed").scale_out(800.0, 3).scale_in(1500.0, 0)
    got = _vals(run_holon(cfg, q, scen))
    _check_byte_identical(oracle, got)
