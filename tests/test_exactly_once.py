"""Exactly-once under failures (paper §3.3, §4.3).

Property: for ANY failure schedule (crashes, restarts, work stealing), the
deduplicated output stream equals the failure-free oracle run, and the system
keeps making progress as long as one node survives.
"""
import numpy as np
import pytest
from _prop import given, settings, st

from repro.runtime import FailureScenario, SimConfig, run_flink, run_holon
from repro.streaming import generate_log, make_q1_ratio, make_q4, make_q7, NexmarkConfig

settings.register_profile("ci-e2e", max_examples=5, deadline=None)
settings.load_profile("ci-e2e")

SMALL = SimConfig(
    num_nodes=3,
    num_partitions=6,
    num_batches=60,
    events_per_batch=512,
    rate_per_partition=10_000.0,
    window_len=500,
    num_slots=32,
    ckpt_interval_ms=300.0,
    sync_interval_ms=50.0,
)


def _records_by_key(consumer):
    return {k: np.asarray(r.value) for k, r in consumer.records.items()}


@pytest.fixture(scope="module")
def q7_baseline():
    q = make_q7(SMALL.num_partitions, window_len=SMALL.window_len, num_slots=SMALL.num_slots)
    return q, run_holon(SMALL, q)


def test_failure_free_matches_oracle(q7_baseline):
    q, consumer = q7_baseline
    nx = NexmarkConfig(
        num_partitions=SMALL.num_partitions,
        num_batches=SMALL.num_batches,
        events_per_batch=SMALL.events_per_batch,
        rate_per_partition=SMALL.rate_per_partition,
        seed=SMALL.seed,
    )
    log = generate_log(nx)
    assert len(consumer.records) > 0
    checked = 0
    for (p, w), rec in consumer.records.items():
        if p == 0 and w < 4:
            ov, oi = q.oracle(log, w)
            np.testing.assert_allclose(rec.value[:8], np.asarray(ov), rtol=1e-5)
            checked += 1
    assert checked > 0


@given(
    fail_t=st.floats(500.0, 1500.0),
    restart_dt=st.floats(300.0, 2000.0),
    node=st.integers(0, 2),
)
def test_exactly_once_single_failure(q7_baseline, fail_t, restart_dt, node):
    q, base = q7_baseline
    scen = FailureScenario(
        name="hyp",
        fail_times_ms=(fail_t,),
        fail_nodes=(node,),
        restart_times_ms=(fail_t + restart_dt,),
    )
    c = run_holon(SMALL, q, scen)
    ref = _records_by_key(base)
    got = _records_by_key(c)
    # every window emitted in the failure-free run is also emitted here, with
    # identical (deduplicated) values
    missing = set(ref) - set(got)
    assert not missing, f"lost outputs: {sorted(missing)[:5]}"
    for k in ref:
        np.testing.assert_allclose(got[k], ref[k], rtol=1e-5, err_msg=str(k))


def test_exactly_once_crash_without_restart(q7_baseline):
    q, base = q7_baseline
    scen = FailureScenario(
        name="crash1", fail_times_ms=(800.0,), fail_nodes=(0,), restart_times_ms=(-1.0,)
    )
    c = run_holon(SMALL, q, scen, horizon_ms=SMALL.horizon_ms + 10_000)
    ref = _records_by_key(base)
    got = _records_by_key(c)
    assert set(ref) <= set(got)
    for k in ref:
        np.testing.assert_allclose(got[k], ref[k], rtol=1e-5)


def test_duplicates_are_deduped(q7_baseline):
    """Concurrent processing of the same partition yields duplicate emissions
    that the consumer drops — outputs stay exactly-once."""
    q, base = q7_baseline
    scen = FailureScenario(
        name="both", fail_times_ms=(700.0, 900.0), fail_nodes=(0, 1),
        restart_times_ms=(1500.0, 1800.0),
    )
    c = run_holon(SMALL, q, scen)
    # duplicates may or may not occur, but records must match baseline values
    ref = _records_by_key(base)
    got = _records_by_key(c)
    for k in ref:
        assert k in got
        np.testing.assert_allclose(got[k], ref[k], rtol=1e-5)


def test_q4_and_ratio_exactly_once():
    for mk in (make_q4, make_q1_ratio):
        q = mk(SMALL.num_partitions, window_len=SMALL.window_len, num_slots=SMALL.num_slots)
        base = run_holon(SMALL, q)
        scen = FailureScenario.concurrent(t=800.0)
        c = run_holon(SMALL, q, scen)
        ref = _records_by_key(base)
        got = _records_by_key(c)
        assert set(ref) <= set(got)
        for k in ref:
            np.testing.assert_allclose(got[k], ref[k], rtol=1e-5)


def test_holon_progress_under_crash_flink_stalls():
    """Fig. 6 bottom-right: with both of two failed nodes never restarting,
    Holon reconfigures and keeps emitting; Flink (no spare slots) stops."""
    q = make_q7(SMALL.num_partitions, window_len=SMALL.window_len, num_slots=SMALL.num_slots)
    scen = FailureScenario.crash(t=800.0)
    ch = run_holon(SMALL, q, scen, horizon_ms=SMALL.horizon_ms + 15_000)
    cf = run_flink(SMALL, q, scen, horizon_ms=SMALL.horizon_ms + 15_000)
    horizon_windows = int(SMALL.horizon_ms / SMALL.window_len)
    late_holon = [w for (_, w) in ch.records if w > horizon_windows // 2]
    late_flink = [w for (_, w) in cf.records if w > horizon_windows // 2]
    assert late_holon, "holon should keep completing windows after the crash"
    assert not late_flink, "flink without spare slots must stall"
