"""Streaming substrate: Nexmark event model, deterministic logged streams,
the paper's global-aggregation queries (Q0/Q4/Q7 + the Query-1 running
example) over Windowed CRDTs, and the Flink-like centralized baseline."""
from repro.streaming.events import EventBatch, KIND_BID, KIND_AUCTION, KIND_PERSON
from repro.streaming.generator import NexmarkConfig, generate_log
from repro.streaming.queries import (
    Query,
    make_q0,
    make_q1_ratio,
    make_q4,
    make_q5,
    make_q7,
)

__all__ = [
    "EventBatch",
    "KIND_BID",
    "KIND_AUCTION",
    "KIND_PERSON",
    "NexmarkConfig",
    "generate_log",
    "Query",
    "make_q0",
    "make_q5",
    "make_q1_ratio",
    "make_q4",
    "make_q7",
]
