"""Deterministic Nexmark event generator.

Produces the full logged input stream up front: per partition, an
``EventBatch`` with leading ``[num_batches]`` axis.  Determinism is total —
``(seed, partition, batch_index)`` fixes every event — which is what makes
replay-based exactly-once recovery testable against a failure-free oracle.

Shape of the generated load (mirrors the paper's setup §5.1): each partition
emits ``events_per_batch`` events per batch, timestamps spaced so a partition
produces ``rate_per_partition`` events/sec of event time; the Nexmark kind mix
is the standard 1 person : 3 auctions : 46 bids per 50 events.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.streaming.events import EventBatch, KIND_AUCTION, KIND_BID, KIND_PERSON

NUM_CATEGORIES = 5  # Nexmark default category count


@dataclasses.dataclass(frozen=True)
class NexmarkConfig:
    num_partitions: int = 8
    num_batches: int = 64
    events_per_batch: int = 256
    rate_per_partition: float = 10_000.0  # events / second (event time)
    seed: int = 0
    base_ts: int = 0
    # zipf exponent of per-partition LOAD: partition p carries a
    # (p+1)^-skew fraction of valid events (0 = uniform, every event valid).
    # Batch shapes and spans are unchanged — cold partitions just pad with
    # invalid events, spread evenly so watermarks still track the span.
    # This shapes WHERE events land, not WHICH keys are hot — that is
    # ``key_skew`` below.
    skew: float = 0.0
    # auction-id (key) domain size: bids/auctions draw ids in
    # [0, num_auctions).  The default reproduces the historical generator
    # bit-for-bit; raise it to stress the keyed/sharded dataplane at
    # realistic cardinalities (docs/protocol.md §6).
    num_auctions: int = 1000
    # zipf exponent of KEY popularity: with key_skew == 0 auction ids are
    # uniform (the historical behaviour, bit-identical draws); with s > 0
    # ids follow the inverse CDF of the continuous power law x^-s on
    # [1, num_auctions + 1), so id k is drawn with probability ~ (k+1)^-s —
    # hot keys are the LOW ids, everywhere in every partition.  Orthogonal
    # to ``skew``, which starves whole partitions of events but leaves the
    # conditional key distribution untouched.
    key_skew: float = 0.0

    @property
    def batch_span_ms(self) -> float:
        return 1000.0 * self.events_per_batch / self.rate_per_partition


def _gen_batch(cfg: NexmarkConfig, partition: jax.Array, batch_idx: jax.Array) -> EventBatch:
    B = cfg.events_per_batch
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), partition), batch_idx
    )
    k_price, k_auct, k_bidder, k_jit = jax.random.split(key, 4)

    # Event-time stamps: evenly spaced within the batch span + small jitter,
    # then sorted (the paper assumes partition-ordered streams).
    span = cfg.batch_span_ms
    base = jnp.float32(cfg.base_ts) + batch_idx.astype(jnp.float32) * span
    offs = jnp.arange(B, dtype=jnp.float32) * (span / B)
    jitter = jax.random.uniform(k_jit, (B,), minval=0.0, maxval=span / B)
    ts = jnp.sort(base + offs + jitter).astype(jnp.int32)

    # Standard Nexmark mix: of every 50 events, 1 person, 3 auctions, 46 bids.
    lane = jnp.arange(B) % 50
    kind = jnp.where(lane == 0, KIND_PERSON, jnp.where(lane < 4, KIND_AUCTION, KIND_BID))

    if cfg.key_skew == 0.0:
        # uniform ids — the exact historical draw (bit-identical at defaults)
        auction = jax.random.randint(k_auct, (B,), 0, cfg.num_auctions).astype(jnp.uint32)
    else:
        # zipf-like hot keys: inverse CDF of the continuous power law x^-s
        # on [1, N+1); id = floor(x) - 1 is drawn with mass ~ (id+1)^-s
        N, s = float(cfg.num_auctions), cfg.key_skew
        u = jax.random.uniform(k_auct, (B,))
        if s == 1.0:
            x = jnp.exp(u * jnp.log(N + 1.0))
        else:
            x = (u * ((N + 1.0) ** (1.0 - s) - 1.0) + 1.0) ** (1.0 / (1.0 - s))
        auction = jnp.clip(
            jnp.floor(x) - 1.0, 0.0, N - 1.0
        ).astype(jnp.uint32)
    # Nexmark assigns categories to auctions round-robin -> derive from id.
    category = (auction % NUM_CATEGORIES).astype(jnp.int32)
    price = jnp.exp(jax.random.normal(k_price, (B,)) * 1.0 + 4.0).astype(jnp.float32)
    bidder = jax.random.randint(k_bidder, (B,), 0, 10_000).astype(jnp.uint32)

    # Skewed load: partition p keeps a (p+1)^-skew fraction of its events,
    # Bresenham-spread across the (sorted) batch, with the last event always
    # kept — so even an extremely cold partition advances its watermark to
    # the span's end every batch and can never freeze the global watermark.
    frac = (partition.astype(jnp.float32) + 1.0) ** jnp.float32(-cfg.skew)
    lane_f = jnp.arange(B, dtype=jnp.float32)
    valid = jnp.floor((lane_f + 1.0) * frac) > jnp.floor(lane_f * frac)
    valid = valid | (jnp.arange(B) == B - 1)

    return EventBatch(
        ts=ts,
        kind=kind.astype(jnp.int32),
        auction=auction,
        price=price,
        category=category,
        bidder=bidder,
        valid=valid,
    )


def generate_log(cfg: NexmarkConfig) -> EventBatch:
    """Full input log: EventBatch with leading [num_partitions, num_batches]."""
    parts = jnp.arange(cfg.num_partitions)
    batches = jnp.arange(cfg.num_batches)
    fn = lambda p, b: _gen_batch(cfg, p, b)
    return jax.vmap(lambda p: jax.vmap(lambda b: fn(p, b))(batches))(parts)


def batch_watermark(batch: EventBatch) -> jax.Array:
    """Largest event time in the batch (partition-ordered streams -> this is
    the partition's local watermark after processing the batch)."""
    return jnp.max(jnp.where(batch.valid, batch.ts, -(2**31)))
