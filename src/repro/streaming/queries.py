"""Nexmark global-aggregation queries over Windowed CRDTs (paper §5.1).

Each query is a :class:`Query`: per-partition replica state split into

* ``shared`` — a tuple of WCRDT replicas (synchronized by lattice joins in the
  background, never by shuffles), and
* ``local``  — partition-local windowed state (the paper's ``WLocal``; realized
  as a WCRDT with a single progress entry, i.e. ``P=1``).

``fold`` consumes one input batch (insert + increment_watermark), ``merge``
joins two replicas' shared parts, ``read`` returns a completed window's value.
The queries:

* **Q0**  pass-through (stateless; per-window event counts via WLocal).
* **Q4**  average price per category — global keyed aggregation *without* a
  shuffle: per-category sum and count lattices.
* **Q7**  highest bids — global top-k lattice per window.
* **Q1-ratio** — the paper's running example (Listing 2): partition-local bid
  count over global bid count.
* **Q5**  hot items — per-auction bid counts + top-1 over an overlapping
  sliding (hopping) window, the classic Nexmark query tumbling windows
  cannot express.

Windowing is a first-class :class:`~repro.core.window.WindowAssigner`
(DESIGN.md §8): every maker takes ``hop`` (None/0/window_len = tumbling,
anything else = hopping), and every oracle masks events with
``assigner.contains(wid, ts)`` so ground truth generalizes with the query.

Every query also ships an ``oracle``: the same aggregation computed directly
over the whole log with plain jnp — the ground truth for exactly-once and
determinism tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import wcrdt as W
from repro.core.wcrdt import WSpec, WState
from repro.core.window import WindowAssigner, as_assigner
from repro.streaming.events import KIND_BID, EventBatch
from repro.streaming.generator import NUM_CATEGORIES, batch_watermark


@dataclasses.dataclass(frozen=True)
class Query:
    name: str
    num_partitions: int
    window_len: int
    assigner: WindowAssigner
    shared_specs: tuple[WSpec, ...]
    local_spec: WSpec | None
    init_shared: Callable[[], tuple[WState, ...]]
    init_local: Callable[[], WState | None]
    fold: Callable[..., tuple[tuple[WState, ...], WState | None]]
    read: Callable[..., tuple[Any, jax.Array]]
    oracle: Callable[..., Any]
    out_width: int  # flattened f32 output lanes per (partition, window)

    # ---- generic helpers ----
    def merge_shared(self, a: tuple[WState, ...], b: tuple[WState, ...]):
        return tuple(
            W.merge(spec, x, y) for spec, x, y in zip(self.shared_specs, a, b)
        )

    def global_watermark(self, shared, local) -> jax.Array:
        if self.shared_specs:
            return W.global_watermark(self.shared_specs[0], shared[0])
        return W.global_watermark(self.local_spec, local)

    def window_of(self, ts):
        """Newest window containing ``ts`` (the only one, under Tumbling)."""
        return self.assigner.window_of(jnp.asarray(ts, jnp.int32))


def _mk_local_spec(kind: str, window_len: int, num_slots: int, **kw) -> WSpec:
    ctor = {"gcounter": W.wgcounter, "maxreg": W.wmaxreg}[kind]
    return ctor(window_len, num_slots, 1, **kw)


# ---------------------------------------------------------------------------
# Q0: pass-through
# ---------------------------------------------------------------------------


def make_q0(
    num_partitions: int, window_len: int = 1000, num_slots: int = 16,
    hop: int | None = None,
) -> Query:
    assigner = as_assigner(window_len, hop)
    lspec = _mk_local_spec("gcounter", window_len, num_slots, assigner=assigner)

    def init_local():
        return lspec.zero()

    def fold(shared, local, batch: EventBatch, partition, batch_idx=None):
        amounts = jnp.ones_like(batch.price)
        local = W.insert(
            lspec, local, 0, batch.ts, batch.valid, batch_idx=batch_idx,
            actor=0, amounts=amounts,
        )
        local = W.increment_watermark(lspec, local, 0, batch_watermark(batch))
        return shared, local

    def read(shared, local, wid):
        v, ok = W.window_value(lspec, local, wid)
        return jnp.reshape(v, (1,)), ok

    def oracle(log: EventBatch, wid, partition=None):
        m = log.valid & assigner.contains(wid, log.ts)
        if partition is not None:
            m = m[partition]
        return jnp.sum(m.astype(jnp.float32))

    return Query(
        name="q0",
        num_partitions=num_partitions,
        window_len=window_len,
        assigner=assigner,
        shared_specs=(),
        local_spec=lspec,
        init_shared=lambda: (),
        init_local=init_local,
        fold=fold,
        read=read,
        oracle=oracle,
        out_width=1,
    )


# ---------------------------------------------------------------------------
# Q4: average price per category (global, keyed, no shuffle)
# ---------------------------------------------------------------------------


def make_q4(
    num_partitions: int,
    window_len: int = 1000,
    num_slots: int = 16,
    num_categories: int = NUM_CATEGORIES,
    hop: int | None = None,
) -> Query:
    assigner = as_assigner(window_len, hop)
    sum_spec = W.wgcounter(window_len, num_slots, num_partitions,
                           key_shape=(num_categories,), assigner=assigner)
    cnt_spec = W.wgcounter(window_len, num_slots, num_partitions,
                           key_shape=(num_categories,), assigner=assigner)

    def init_shared():
        return (sum_spec.zero(), cnt_spec.zero())

    def fold(shared, local, batch: EventBatch, partition, batch_idx=None):
        s, c = shared
        is_bid = batch.valid & (batch.kind == KIND_BID)
        wm = batch_watermark(batch)
        s = W.insert(
            sum_spec, s, partition, batch.ts, is_bid, batch_idx=batch_idx,
            actor=partition, amounts=batch.price, keys=batch.category,
        )
        s = W.increment_watermark(sum_spec, s, partition, wm)
        c = W.insert(
            cnt_spec, c, partition, batch.ts, is_bid, batch_idx=batch_idx,
            actor=partition, amounts=jnp.ones_like(batch.price), keys=batch.category,
        )
        c = W.increment_watermark(cnt_spec, c, partition, wm)
        return (s, c), local

    def read(shared, local, wid):
        s, c = shared
        sv, ok1 = W.window_value(sum_spec, s, wid)
        cv, ok2 = W.window_value(cnt_spec, c, wid)
        avg = sv / jnp.maximum(cv, 1.0)
        return avg, ok1 & ok2

    def oracle(log: EventBatch, wid, partition=None):
        m = log.valid & (log.kind == KIND_BID) & assigner.contains(wid, log.ts)
        cat_onehot = jax.nn.one_hot(log.category, num_categories, dtype=jnp.float32)
        w = m.astype(jnp.float32)[..., None] * cat_onehot
        sums = jnp.sum(w * log.price[..., None], axis=tuple(range(w.ndim - 1)))
        cnts = jnp.sum(w, axis=tuple(range(w.ndim - 1)))
        return sums / jnp.maximum(cnts, 1.0)

    return Query(
        name="q4",
        num_partitions=num_partitions,
        window_len=window_len,
        assigner=assigner,
        shared_specs=(sum_spec, cnt_spec),
        local_spec=None,
        init_shared=init_shared,
        init_local=lambda: None,
        fold=fold,
        read=read,
        oracle=oracle,
        out_width=num_categories,
    )


# ---------------------------------------------------------------------------
# Q7: highest bids (global top-k per window)
# ---------------------------------------------------------------------------


def make_q7(
    num_partitions: int, window_len: int = 1000, num_slots: int = 16, k: int = 8,
    topk_active: int = 4, hop: int | None = None,
) -> Query:
    """``topk_active``: window offsets folded per batch.  A partition-ordered
    batch spans ceil(batch_span/window_len)+1 windows; 2 suffices for the
    default rates (batch span ~0.1-0.2 windows) and is 1.7x faster than 8
    (EXPERIMENTS.md §Perf iteration C); 4 is the safe default.  Under a
    hopping assigner each event multi-emits into window_len // hop windows,
    so the active span grows by that factor — clamped to the ring size,
    since TopK's fast fold requires distinct slots per active offset
    (offsets beyond the ring would alias and drop folds)."""
    assigner = as_assigner(window_len, hop)
    if topk_active is not None:  # None = wtopk's exact unbounded fold path
        topk_active = min(topk_active * assigner.windows_per_event, num_slots)
    topk_spec = W.wtopk(window_len, num_slots, num_partitions, k,
                        max_active_windows=topk_active, assigner=assigner)

    def init_shared():
        return (topk_spec.zero(),)

    def fold(shared, local, batch: EventBatch, partition, batch_idx=None):
        (t,) = shared
        is_bid = batch.valid & (batch.kind == KIND_BID)
        t = W.insert(
            topk_spec, t, partition, batch.ts, is_bid, batch_idx=batch_idx,
            vals=batch.price, ids=batch.auction,
        )
        t = W.increment_watermark(topk_spec, t, partition, batch_watermark(batch))
        return (t,), local

    def read(shared, local, wid):
        (t,) = shared
        (vals, ids), ok = W.window_value(topk_spec, t, wid)
        out = jnp.concatenate([vals, ids.astype(jnp.float32)])
        return out, ok

    def oracle(log: EventBatch, wid, partition=None):
        m = log.valid & (log.kind == KIND_BID) & assigner.contains(wid, log.ts)
        prices = jnp.where(m, log.price, -jnp.inf).reshape(-1)
        ids = jnp.where(m, log.auction, 0).reshape(-1)
        sv, si = jax.lax.sort((prices, ids.astype(jnp.uint32)), dimension=-1, num_keys=2)
        return sv[-k:][::-1], si[-k:][::-1]

    return Query(
        name="q7",
        num_partitions=num_partitions,
        window_len=window_len,
        assigner=assigner,
        shared_specs=(topk_spec,),
        local_spec=None,
        init_shared=init_shared,
        init_local=lambda: None,
        fold=fold,
        read=read,
        oracle=oracle,
        out_width=2 * k,
    )


# ---------------------------------------------------------------------------
# Query 1 (paper Listing 2): local/global bid-count ratio
# ---------------------------------------------------------------------------


def make_q1_ratio(
    num_partitions: int, window_len: int = 1000, num_slots: int = 16,
    hop: int | None = None,
) -> Query:
    assigner = as_assigner(window_len, hop)
    gspec = W.wgcounter(window_len, num_slots, num_partitions, assigner=assigner)
    lspec = _mk_local_spec("gcounter", window_len, num_slots, assigner=assigner)

    def init_shared():
        return (gspec.zero(),)

    def init_local():
        return lspec.zero()

    def fold(shared, local, batch: EventBatch, partition, batch_idx=None):
        (g,) = shared
        is_bid = batch.valid & (batch.kind == KIND_BID)
        wm = batch_watermark(batch)
        ones = jnp.ones_like(batch.price)
        g = W.insert(gspec, g, partition, batch.ts, is_bid, batch_idx=batch_idx,
                     actor=partition, amounts=ones)
        g = W.increment_watermark(gspec, g, partition, wm)
        local = W.insert(lspec, local, 0, batch.ts, is_bid, batch_idx=batch_idx,
                         actor=0, amounts=ones)
        local = W.increment_watermark(lspec, local, 0, wm)
        return (g,), local

    def read(shared, local, wid):
        (g,) = shared
        gv, ok1 = W.window_value(gspec, g, wid)
        lv, ok2 = W.window_value(lspec, local, wid)
        ratio = lv / jnp.maximum(gv, 1.0)
        return jnp.reshape(ratio, (1,)), ok1 & ok2

    def oracle(log: EventBatch, wid, partition=None):
        m = log.valid & (log.kind == KIND_BID) & assigner.contains(wid, log.ts)
        total = jnp.sum(m.astype(jnp.float32))
        if partition is None:
            return total
        loc = jnp.sum(m[partition].astype(jnp.float32))
        return loc / jnp.maximum(total, 1.0)

    return Query(
        name="q1_ratio",
        num_partitions=num_partitions,
        window_len=window_len,
        assigner=assigner,
        shared_specs=(gspec,),
        local_spec=lspec,
        init_shared=init_shared,
        init_local=init_local,
        fold=fold,
        read=read,
        oracle=oracle,
        out_width=1,
    )

# ---------------------------------------------------------------------------
# Q5: hot items — top-1 auction by bid count over a sliding (hopping) window
# ---------------------------------------------------------------------------


def make_q5(
    num_partitions: int, window_len: int = 1000, num_slots: int = 16,
    hop: int | None = None, num_auctions: int = 64,
) -> Query:
    """Nexmark Q5: which auction received the most bids in each sliding
    window?  The query overlapping windows exist for — a tumbling window
    misses bursts straddling window edges.

    Defaults to ``hop = window_len // 2`` (each event lives in 2 windows);
    pass ``hop=window_len`` for the tumbling degenerate.  State is one
    per-auction keyed count lattice (GCounter, no shuffle); the read takes
    the argmax — output lanes are ``[count, auction_bucket]``.  Auction ids
    are bucketed ``auction % num_auctions`` to keep the keyed state dense
    (DESIGN.md §8 records the deviation); the oracle buckets identically,
    and counts are small integers, exact in f32 — so replica reads are
    byte-identical to the oracle under any merge order.
    """
    hop = window_len // 2 if hop is None else hop
    assigner = as_assigner(window_len, hop)
    cnt_spec = W.wgcounter(window_len, num_slots, num_partitions,
                           key_shape=(num_auctions,), assigner=assigner)

    def init_shared():
        return (cnt_spec.zero(),)

    def fold(shared, local, batch: EventBatch, partition, batch_idx=None):
        (c,) = shared
        is_bid = batch.valid & (batch.kind == KIND_BID)
        bucket = (batch.auction % num_auctions).astype(jnp.int32)
        c = W.insert(
            cnt_spec, c, partition, batch.ts, is_bid, batch_idx=batch_idx,
            actor=partition, amounts=jnp.ones_like(batch.price), keys=bucket,
        )
        c = W.increment_watermark(cnt_spec, c, partition, batch_watermark(batch))
        return (c,), local

    def read(shared, local, wid):
        (c,) = shared
        counts, ok = W.window_value(cnt_spec, c, wid)
        hot = jnp.argmax(counts)  # ties -> lowest bucket, same as the oracle
        out = jnp.stack([counts[hot], hot.astype(jnp.float32)])
        return out, ok

    def oracle(log: EventBatch, wid, partition=None):
        m = log.valid & (log.kind == KIND_BID) & assigner.contains(wid, log.ts)
        bucket = (log.auction % num_auctions).astype(jnp.int32)
        onehot = jax.nn.one_hot(bucket, num_auctions, dtype=jnp.float32)
        cnts = jnp.sum(
            m.astype(jnp.float32)[..., None] * onehot,
            axis=tuple(range(onehot.ndim - 1)),
        )
        hot = jnp.argmax(cnts)
        return jnp.stack([cnts[hot], hot.astype(jnp.float32)])

    return Query(
        name="q5",
        num_partitions=num_partitions,
        window_len=window_len,
        assigner=assigner,
        shared_specs=(cnt_spec,),
        local_spec=None,
        init_shared=init_shared,
        init_local=lambda: None,
        fold=fold,
        read=read,
        oracle=oracle,
        out_width=2,
    )


def q5_hot_oracle(
    log: EventBatch, wid, assigner: WindowAssigner, num_keys: int
) -> jax.Array:
    """Sparse Q5 ground truth over the FULL auction-id domain — the oracle
    for the hash-sharded keyed dataplane (docs/protocol.md §6), which routes
    real ids instead of bucketing them ``% num_auctions`` like
    :func:`make_q5`.  Segment-sum instead of a ``[B, C]`` one-hot, so it
    stays cheap at C = 1e6+.  Returns ``[count, auction_id]``; ties break to
    the lowest id (``argmax``), the same rule :func:`W.shard_topk_read`
    implements shard-side, and counts are small integers exact in f32 — so
    sharded reads are byte-identical to this oracle.
    """
    m = log.valid & (log.kind == KIND_BID) & assigner.contains(wid, log.ts)
    cnts = jax.ops.segment_sum(
        m.astype(jnp.float32).reshape(-1),
        log.auction.astype(jnp.int32).reshape(-1),
        num_segments=num_keys,
    )
    hot = jnp.argmax(cnts)
    return jnp.stack([cnts[hot], hot.astype(jnp.float32)])
