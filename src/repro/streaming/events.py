"""Nexmark event model as struct-of-arrays (JAX-friendly).

The paper's logged input streams are Kafka topics of Nexmark [47] events.
Here a *log* is a pre-generated, deterministically indexable array batch per
partition — exactly the replayable-log property exactly-once recovery needs
(DESIGN.md §3).  Events are a tagged union over (person, auction, bid); the
global-aggregation queries consume bids, with the auction→category join
pre-resolved by the generator the way Nexmark's generator assigns categories
round-robin (the join itself is not a contribution of the paper).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

KIND_PERSON = 0
KIND_AUCTION = 1
KIND_BID = 2


@dataclasses.dataclass(frozen=True)
class EventBatch:
    """A fixed-size batch of events; invalid lanes have valid=False."""

    ts: jax.Array  # i32[B] event time (ms)
    kind: jax.Array  # i32[B] KIND_*
    auction: jax.Array  # u32[B] auction id (bids/auctions)
    price: jax.Array  # f32[B] bid price
    category: jax.Array  # i32[B] auction category (pre-joined)
    bidder: jax.Array  # u32[B] bidder id
    valid: jax.Array  # bool[B]

    @property
    def size(self) -> int:
        return self.ts.shape[-1]

    def slice_rows(self, i) -> "EventBatch":
        return EventBatch(*(getattr(self, f.name)[i] for f in dataclasses.fields(self)))


jax.tree_util.register_dataclass(
    EventBatch,
    data_fields=["ts", "kind", "auction", "price", "category", "bidder", "valid"],
    meta_fields=[],
)


def empty_batch(B: int) -> EventBatch:
    return EventBatch(
        ts=jnp.zeros((B,), jnp.int32),
        kind=jnp.zeros((B,), jnp.int32),
        auction=jnp.zeros((B,), jnp.uint32),
        price=jnp.zeros((B,), jnp.float32),
        category=jnp.zeros((B,), jnp.int32),
        bidder=jnp.zeros((B,), jnp.uint32),
        valid=jnp.zeros((B,), jnp.bool_),
    )
