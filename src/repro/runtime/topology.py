"""Pluggable dissemination topologies for the gossip plane.

Every sync round and heartbeat used to broadcast to *every* subscribed peer
— O(N²) messages per round, fine at N=8, fatal at the N=256 the ROADMAP
targets.  Because replica merge is an idempotent, commutative lattice join
and a delta from an unmoved baseline subsumes any lost predecessor
(docs/protocol.md §2, §4), deltas may ride *any* connected dissemination
graph and pay only in propagation hops, never in correctness.  This module
makes that graph a first-class, configurable axis (docs/protocol.md §5):
:class:`Topology` answers one question — *whom do I contact this round?* —
and the harness consults it from ``_publish_sync`` and ``_broadcast_hb``.

Implementations:

* :class:`AllToAll` — today's behavior and the correctness **oracle**: every
  peer, every round, in registry order (sparse runs must stay byte-identical
  to it on window outputs — tests/test_topology.py).
* :class:`EpochRing` — rotating k-regular circulant: round ``r`` uses
  strides ``r*k+1 .. r*k+k`` (mod N-1), so the union over
  ``ceil((N-1)/k)`` consecutive rounds spans the whole membership and every
  node has exactly ``k`` in- and out-neighbors per round (permutation-fair).
* :class:`Hypercube` — dimension-scheduled exchange: round ``r`` pairs index
  ``i`` with ``i XOR 2^(r mod dim)``; partners are symmetric, and the union
  over ``dim = ceil(log2 N)`` rounds spans a connected graph even for
  non-power-of-two N (clearing the top bit always lands in range).
* :class:`PartialView` — seeded random peer sampling à la gossip: a
  deterministic splitmix64 stream keyed ``(seed, nid, round)`` draws
  ``fanout`` distinct peers — no global RNG, so runs stay replayable.

All schedules are pure functions of ``(nid, round, peers)``: no state, no
RNG objects, so two nodes (or two runs) with the same arguments agree
exactly.  Rounds are derived from sim time (``now // interval``), which
keeps restarted and late-joining nodes on the shared schedule.
"""
from __future__ import annotations

from typing import Sequence

_M64 = (1 << 64) - 1


def _mix64(*xs: int) -> int:
    """splitmix64-style finalizer over a tuple of ints — the same salt-free
    determinism contract as the rendezvous hash in runtime/harness.py."""
    x = 0x9E3779B97F4A7C15
    for v in xs:
        x = (x + (v & _M64) + 0x9E3779B97F4A7C15) & _M64
        x ^= x >> 30
        x = (x * 0xBF58476D1CE4E5B9) & _M64
        x ^= x >> 27
        x = (x * 0x94D049BB133111EB) & _M64
        x ^= x >> 31
    return x


class Topology:
    """Dissemination schedule: ``peers_of(nid, round, peers)`` returns the
    subset of ``peers`` node ``nid`` contacts in gossip round ``round``.

    ``peers`` is the caller's current peer-id list (self excluded); the
    membership it reflects may change between rounds — implementations must
    only ever return ids drawn from it.  ``sparse`` is False only for
    :class:`AllToAll`: sparse topologies additionally piggyback transitive
    liveness gossip on heartbeats (docs/protocol.md §5), which the
    all-to-all oracle provably does not need.
    """

    name: str = "?"
    sparse: bool = True

    def peers_of(self, nid: int, rnd: int, peers: Sequence[int]) -> list[int]:
        raise NotImplementedError

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"{type(self).__name__}({self.name!r})"


class AllToAll(Topology):
    """Every peer, every round — the pre-topology behavior and the oracle
    sparse runs are verified byte-identical against.  Returns ``peers``
    unmodified (same order), so the scheduled event sequence of a default
    run is bit-for-bit the pre-topology one."""

    name = "all"
    sparse = False

    def peers_of(self, nid: int, rnd: int, peers: Sequence[int]) -> list[int]:
        return list(peers)


class EpochRing(Topology):
    """Rotating k-regular ring (circulant graph) over the sorted membership.

    Round ``r`` uses the stride set ``{(r*k + j) mod (N-1) + 1 : j < k}``:
    every node applies the same strides, so per round each node has exactly
    ``k`` out- and ``k`` in-neighbors (permutation-fair), and consecutive
    rounds rotate through all N-1 strides — the union of any
    ``ceil((N-1)/k)`` consecutive rounds is the complete graph."""

    sparse = True

    def __init__(self, k: int = 2):
        if k < 1:
            raise ValueError(f"EpochRing needs k >= 1, got {k}")
        self.k = int(k)
        self.name = f"ring:{self.k}"

    def peers_of(self, nid: int, rnd: int, peers: Sequence[int]) -> list[int]:
        members = sorted(peers)
        if nid not in members:
            members = sorted([nid, *members])
        n = len(members)
        if n - 1 <= self.k:
            return [m for m in members if m != nid]
        i = members.index(nid)
        out: list[int] = []
        for j in range(self.k):
            stride = (rnd * self.k + j) % (n - 1) + 1
            tgt = members[(i + stride) % n]
            if tgt != nid and tgt not in out:
                out.append(tgt)
        return out


class Hypercube(Topology):
    """Dimension-scheduled hypercube exchange over the sorted membership.

    Round ``r`` flips bit ``r mod dim`` of a node's membership index; the
    pairing is symmetric (a talks to b iff b talks to a), and over ``dim``
    consecutive rounds the union of edges is the hypercube skeleton —
    connected even for non-power-of-two N, because clearing a set bit
    always yields a valid (smaller) index.  Out-of-range partners simply
    idle that round; their delta waits one round, never disappears."""

    name = "hypercube"
    sparse = True

    def peers_of(self, nid: int, rnd: int, peers: Sequence[int]) -> list[int]:
        members = sorted(peers)
        if nid not in members:
            members = sorted([nid, *members])
        n = len(members)
        if n <= 1:
            return []
        dim = max(1, (n - 1).bit_length())
        partner = members.index(nid) ^ (1 << (rnd % dim))
        return [members[partner]] if partner < n else []


class PartialView(Topology):
    """Seeded random peer sampling (gossip-style partial view).

    Each round draws ``fanout`` distinct peers by partial Fisher-Yates over
    the sorted peer list, with every swap index taken from a splitmix64
    stream keyed ``(seed, nid, round)`` — per-(node, round) streams are
    independent, deterministic, and shared by no one, so sampling never
    perturbs any other randomness in the run."""

    sparse = True

    def __init__(self, fanout: int = 3, seed: int = 0):
        if fanout < 1:
            raise ValueError(f"PartialView needs fanout >= 1, got {fanout}")
        self.fanout = int(fanout)
        self.seed = int(seed)
        self.name = f"partial:{self.fanout}"

    def peers_of(self, nid: int, rnd: int, peers: Sequence[int]) -> list[int]:
        pool = sorted(p for p in peers if p != nid)
        k = min(self.fanout, len(pool))
        for j in range(k):
            swap = j + _mix64(self.seed, nid, rnd, j) % (len(pool) - j)
            pool[j], pool[swap] = pool[swap], pool[j]
        return pool[:k]


def topology_from_spec(spec: str, seed: int = 0) -> Topology:
    """Parse ``SimConfig.topology`` — ``all``, ``ring[:k]``, ``hypercube``,
    or ``partial[:fanout]`` (docs/protocol.md §5)."""
    name, _, arg = str(spec).strip().partition(":")
    name = name.lower()
    try:
        if name == "all":
            if arg:
                raise ValueError("'all' takes no parameter")
            return AllToAll()
        if name == "ring":
            return EpochRing(int(arg) if arg else 2)
        if name in ("hypercube", "cube"):
            if arg:
                raise ValueError("'hypercube' takes no parameter")
            return Hypercube()
        if name == "partial":
            return PartialView(int(arg) if arg else 3, seed=seed)
    except ValueError as e:
        raise ValueError(f"bad topology spec {spec!r}: {e}") from None
    raise ValueError(
        f"unknown topology {spec!r} (want all | ring[:k] | hypercube | "
        f"partial[:fanout])"
    )
