"""Centralized stream-processing baseline ("Flink-like", paper §5.1).

Models the architecture the paper compares against:

* global aggregation via a **static aggregation tree** (fan-in
  ``flink_tree_fanin``): partitions pre-aggregate locally, forward partials
  up the tree when their local watermark passes the window; the root emits
  once ALL leaves contributed — end-to-end latency is the *slowest path*.
  Each hop pays network latency + the output-buffer flush timeout (Flink's
  default 100 ms execution.buffer-timeout is the dominant term).
* **aligned checkpoints with centralized 2PC** every ``flink_ckpt_interval``:
  a barrier pause for every node.
* **centralized recovery**: heartbeat detection (paper config: 4 s interval /
  6 s timeout) then full-job stop → restore from last completed global
  checkpoint → replay.  Without spare slots a crash leaves the job down
  (Fig. 6 bottom-right); with spare slots failover still pays
  detect + restart + restore.

What differs from Holon is purely the coordination structure — which is the
paper's point: same logs, same windows, same per-batch compute cost.  Both
runtimes also share the same :class:`~repro.runtime.net.NetworkFabric`
(docs/protocol.md §4), so chaos comparisons are apples-to-apples: the tree's
shuffle partials ride the *reliable* tier — a real Flink job runs on TCP, so
message loss surfaces as retransmit latency (one ``net_rto_ms`` per lost
transmission per hop) rather than silent drops, and a network partition
parks partials until heal.  A partition that separates TaskManagers from the
JobManager side (the group holding node 0) is detected like a node failure —
after ``flink_hb_timeout_ms`` the job goes down globally, and recovery can
only start once the fabric heals.

Telemetry (docs/observability.md) mirrors the Holon harness so the auditor
runs over both traces: ``exec.batch`` spans, ``emit`` records with latency
and digest, ``flink.barrier`` per aligned checkpoint, ``node.crash`` /
``node.restart``, and the centralized-specific ``flink.down`` /
``flink.recover`` pair the auditor turns into downtime intervals.
"""
from __future__ import annotations

import math

import numpy as np

from repro.obs.telemetry import Telemetry
from repro.runtime.config import FailureScenario, Scenario, SimConfig, as_scenario
from repro.runtime.consumer import Consumer
from repro.runtime.net import NetworkFabric
from repro.runtime.sim import Sim
from repro.streaming.events import EventBatch
from repro.streaming.generator import NexmarkConfig, generate_log
from repro.streaming.queries import Query

# Flink's default execution.buffer-timeout — dominates small-record latency.
BUFFER_TIMEOUT_MS = 100.0
# nominal wire size of one pre-aggregated window partial sent up the tree
PARTIAL_BYTES = 256.0


class FlinkHarness:
    def __init__(self, cfg: SimConfig, query: Query, log: EventBatch | None = None):
        self.cfg = cfg
        self.query = query
        nx = NexmarkConfig(
            num_partitions=cfg.num_partitions,
            num_batches=cfg.num_batches,
            events_per_batch=cfg.events_per_batch,
            rate_per_partition=cfg.rate_per_partition,
            seed=cfg.seed,
            skew=cfg.skew,
        )
        self.log = log if log is not None else generate_log(nx)
        # same load-proportional batch cost as the Holon runtime, so skewed
        # logs keep the A/B cost models apples-to-apples
        self.valid_frac = np.asarray(self.log.valid, np.float64).mean(axis=-1)
        self.sim = Sim()
        # shared telemetry hub, exactly as in the Holon harness — one ring,
        # one registry, so traces from both runtimes audit identically
        self.obs = Telemetry.from_config(self.sim, cfg)
        # same fabric profile as the Holon runtime (docs/protocol.md §4);
        # the baseline's traffic rides the reliable tier (TCP semantics)
        self.net = NetworkFabric.from_config(self.sim, cfg, telemetry=self.obs)
        self.consumer = Consumer(
            window_len=cfg.window_len, assigner=query.assigner, telemetry=self.obs
        )
        self.tree_depth = max(
            1, math.ceil(math.log(max(cfg.num_partitions, 2), cfg.flink_tree_fanin))
        )

        P = cfg.num_partitions
        self.idx = [0] * P  # next batch per partition
        self.forwarded: set[tuple[int, int]] = set()  # (wid, pid) sent up-tree
        self.arrived: dict[int, set[int]] = {}  # wid -> pids at root
        self.emitted: set[int] = set()
        self.down = False  # global stop flag
        self.job_dead = False
        self.paused_until = 0.0  # checkpoint barrier pause
        self.last_ckpt_idx = [0] * P
        self.node_of = [p % cfg.num_nodes for p in range(P)]
        self.node_alive = [True] * cfg.num_nodes
        # online protocol monitor — same passive subscription as the Holon
        # harness, so both runtimes alert through one code path
        self.monitor = None
        if cfg.obs_monitor:
            from repro.obs.monitor import OnlineMonitor
            self.monitor = OnlineMonitor.from_config(cfg)
            self.monitor.attach(self.obs)

    # ---- per-partition processing loop -------------------------------------
    def _loop_part(self, pid: int):
        cfg = self.cfg
        if self.job_dead or self.down or not self.node_alive[self.node_of[pid]]:
            return
        if self.idx[pid] >= cfg.num_batches:
            return
        if self.sim.now < self.paused_until:  # aligned-barrier stall
            self.sim.at(self.paused_until, lambda: self._loop_part(pid))
            return
        avail = (self.idx[pid] + 1) * cfg.batch_span_ms
        if self.sim.now < avail:
            self.sim.at(avail, lambda: self._loop_part(pid))
            return
        b = self.idx[pid]
        self.idx[pid] += 1
        frac = float(self.valid_frac[pid, b])
        n_events = int(round(frac * cfg.events_per_batch))
        self.consumer.count_events(self.sim.now, n_events)
        proc = max(cfg.batch_proc_ms * frac, cfg.batch_proc_ms / cfg.events_per_batch)
        if self.obs.on:
            nid = self.node_of[pid]
            queue_ms = self.sim.now - (b + 1) * cfg.batch_span_ms
            self.obs.event(
                "exec.batch", node=nid, partition=pid, status="ok",
                t_end_ms=self.sim.now + proc, idx=b, queue_ms=queue_ms,
            )
            reg = self.obs.registry
            reg.counter("batches_folded", node=nid).inc()
            reg.counter("events_folded", node=nid).inc(n_events)
            reg.histogram("phase_ms", phase="queue").observe(queue_ms)
            reg.histogram("phase_ms", phase="process").observe(proc)
        # local watermark after this batch = end of batch span; a leaf
        # forwards every window whose assigner-provided end it has passed
        # (wid < first_dirty_wid(wm) — under tumbling, wm // window_len)
        wm = (b + 1) * cfg.batch_span_ms
        closed = int(self.query.assigner.first_dirty_wid(wm))
        for wid in range(closed):
            if (wid, pid) not in self.forwarded:
                self.forwarded.add((wid, pid))
                if self.obs.on:
                    # up-tree forward: the leaf half of the slowest-path
                    # causality critical-path analysis reconstructs
                    # (obs/critpath.py pairs it with shuffle.arrive)
                    self.obs.event(
                        "shuffle.fwd", node=self.node_of[pid], partition=pid,
                        window=wid, dst=0, hops=self.tree_depth,
                    )
                # tree_depth reliable hops toward the root (node 0): each
                # hop pays network latency + the output-buffer flush, plus
                # one RTO per lost transmission; a partition parks the
                # partial at the fabric until heal
                self.net.send_reliable(
                    self.node_of[pid], 0, "shuffle", PARTIAL_BYTES,
                    lambda w=wid, p=pid: self._arrive(w, p),
                    latency_ms=cfg.shuffle_hop_ms + BUFFER_TIMEOUT_MS,
                    hops=self.tree_depth,
                )
        self.sim.after(proc, lambda: self._loop_part(pid))

    def _arrive(self, wid: int, pid: int):
        if self.job_dead or self.down:
            return
        s = self.arrived.setdefault(wid, set())
        s.add(pid)
        if self.obs.on:
            # root-side arrival: the LAST arrive per window is the slowest
            # path the paper's latency claim is about (obs/critpath.py)
            self.obs.event("shuffle.arrive", node=0, partition=pid, window=wid,
                           src=self.node_of[pid])
        if len(s) >= self.cfg.num_partitions and wid not in self.emitted:
            self.emitted.add(wid)
            fresh = self.consumer.emit(self.sim.now, 0, wid, None)
            if self.obs.on:
                # root emission: value digest is 0 — the modeled tree ships
                # partials, not materialized values (latency is the metric)
                self.obs.event(
                    "emit", node=0, partition=0, window=wid,
                    status="accepted" if fresh else "duplicate",
                    latency_ms=max(
                        0.0,
                        self.sim.now - float(self.query.assigner.end_ts(wid)),
                    ),
                    digest=0,
                )

    # ---- checkpoint barrier -------------------------------------------------
    def _loop_ckpt(self):
        if self.job_dead:
            return
        cfg = self.cfg
        if not self.down:
            self.last_ckpt_idx = list(self.idx)
            self.paused_until = self.sim.now + cfg.flink_barrier_pause_ms
            if self.obs.on:
                self.obs.event(
                    "flink.barrier", node=0, t_end_ms=self.paused_until,
                    frontier=tuple(self.last_ckpt_idx),
                )
                self.obs.registry.counter("ckpt_barriers").inc()
        self.sim.after(cfg.flink_ckpt_interval_ms, self._loop_ckpt)

    # ---- failure handling -----------------------------------------------------
    def fail_node(self, nid: int):
        if self.obs.on:
            # owned=() — centralized recovery has no per-partition steal, so
            # the auditor tracks downtime via flink.down/flink.recover instead
            self.obs.event("node.crash", node=nid, owned=())
        self.node_alive[nid] = False
        self.sim.after(self.cfg.flink_hb_timeout_ms, lambda: self._detect())

    def restart_node(self, nid: int):
        if self.obs.on:
            self.obs.event("node.restart", node=nid)
        self.node_alive[nid] = True
        if self.down and not self.job_dead:
            self._recover()

    def _detect(self):
        if self.job_dead or self.down:
            return
        self.down = True
        if self.obs.on:
            self.obs.event("flink.down", node=0, status="node_failure")
        if all(self.node_alive) or self.cfg.flink_spare_slots:
            self._recover()
        # else: job stays down until a node restarts (or forever — Fig. 6)

    # ---- network partitions (docs/protocol.md §4) --------------------------
    def _jm_separated(self) -> bool:
        """Is any alive TaskManager unreachable from the JobManager side
        (the partition group holding node 0)?"""
        return self.net.partitioned() and any(
            self.node_alive[n] and not self.net.reachable(n, 0)
            for n in range(self.cfg.num_nodes)
        )

    def _on_partition(self, groups):
        self.net.set_partition(*groups)
        self.sim.after(self.cfg.flink_hb_timeout_ms, self._detect_partition)

    def _detect_partition(self):
        # JM heartbeats time out across the cut: global stop, like a crash —
        # but recovery cannot complete until the fabric heals
        if not self.job_dead and not self.down and self._jm_separated():
            self.down = True
            if self.obs.on:
                self.obs.event("flink.down", node=0, status="jm_partition")

    def _on_heal(self):
        self.net.heal()
        if self.down and not self.job_dead:
            self._recover()

    def _recover(self):
        cfg = self.cfg

        def up():
            if self.job_dead or not self.down:
                return
            if not (all(self.node_alive) or cfg.flink_spare_slots):
                return
            if self._jm_separated():
                return  # still partitioned; the heal event retries recovery
            self.down = False
            if self.obs.on:
                self.obs.event("flink.recover", node=0,
                               frontier=tuple(self.last_ckpt_idx))
            # spare slots: reassign dead nodes' partitions to live nodes
            live = [n for n in range(cfg.num_nodes) if self.node_alive[n]]
            for pid in range(cfg.num_partitions):
                if not self.node_alive[self.node_of[pid]]:
                    self.node_of[pid] = live[pid % len(live)]
            self.idx = list(self.last_ckpt_idx)
            # partials not yet emitted are lost with operator state -> replayed
            self.forwarded = {(w, p) for (w, p) in self.forwarded if w in self.emitted}
            self.arrived = {w: s for w, s in self.arrived.items() if w in self.emitted}
            for pid in range(cfg.num_partitions):
                self.sim.after(0.0, lambda p=pid: self._loop_part(p))

        self.sim.after(cfg.flink_restart_ms + cfg.flink_restore_ms, up)

    # ---- driver ---------------------------------------------------------------
    def run(
        self,
        scenario: Scenario | FailureScenario | None = None,
        horizon_ms: float | None = None,
    ):
        scenario = as_scenario(scenario)
        cfg = self.cfg
        for pid in range(cfg.num_partitions):
            self.sim.after(0.0, lambda p=pid: self._loop_part(p))
        self.sim.after(cfg.flink_ckpt_interval_ms, self._loop_ckpt)
        for ev in scenario.events:
            if ev.kind == "crash":
                for nid in ev.nodes:
                    self.sim.at(ev.t_ms, lambda n=nid: self.fail_node(n))
            elif ev.kind == "restart":
                for nid in ev.nodes:
                    self.sim.at(ev.t_ms, lambda n=nid: self.restart_node(n))
            elif ev.kind == "partition":
                self.sim.at(ev.t_ms, lambda gs=ev.groups: self._on_partition(gs))
            elif ev.kind == "heal":
                self.sim.at(ev.t_ms, self._on_heal)
            elif ev.kind == "degrade":
                self.sim.at(
                    ev.t_ms,
                    lambda e=ev: self.net.degrade(
                        e.nodes, loss=e.loss, jitter_ms=e.jitter_ms
                    ),
                )
            else:
                raise ValueError(
                    f"Flink baseline is fixed-membership; {ev.kind!r} events "
                    "only apply to the Holon runtime"
                )
        horizon = horizon_ms if horizon_ms is not None else cfg.horizon_ms + 5000.0
        self.obs.start_snapshots()
        self.sim.run(until=horizon)
        self.obs.buf.flush_spill()
        self.consumer.net_stats = self.net.class_stats()
        return self.consumer


def run_flink(
    cfg: SimConfig, query: Query, scenario: Scenario | FailureScenario | None = None,
    horizon_ms: float | None = None, log: EventBatch | None = None,
) -> Consumer:
    h = FlinkHarness(cfg, query, log=log)
    return h.run(scenario, horizon_ms)
