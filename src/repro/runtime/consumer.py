"""Output-stream consumer: dedup + end-to-end latency / throughput metrics.

The paper considers duplicated outputs exactly-once because a consumer can
deduplicate by (partition, window) tags (§3.3).  This consumer implements
exactly that and doubles as the measurement probe: end-to-end latency of a
window = first emission sim-time − window-close event-time (the analogue of
the paper's Kafka-insertion-timestamp latency).

With telemetry attached (docs/observability.md §1) every accepted/duplicate
emission also feeds the metrics registry (``windows_emitted`` /
``windows_duplicate`` counters, ``emit_lag_ms`` phase histogram); the
percentile summaries behind ``latency_stats`` are the shared
:func:`repro.obs.registry.summary` implementation, so benchmark rows and
consumer probes can never disagree on how a p99 is computed.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.obs.registry import summary


@dataclasses.dataclass
class WindowRecord:
    partition: int
    window: int
    value: Any
    emit_time: float
    latency: float
    duplicates: int = 0


class Consumer:
    def __init__(self, window_len: float, assigner=None, telemetry=None):
        # ``assigner`` (core.window.WindowAssigner) supplies window extents;
        # None keeps the tumbling arithmetic for legacy callers.
        self.window_len = window_len
        self.assigner = assigner
        self.obs = telemetry  # Telemetry or None (docs/observability.md §1)
        self.records: dict[tuple[int, int], WindowRecord] = {}
        self.events_consumed: list[tuple[float, int]] = []  # (time, count)
        self.duplicates = 0
        # sync-bandwidth probe, filled by the runtime at end of run:
        # bytes actually shipped (delta or full) vs the full-state cost
        self.sync_msgs = 0
        self.sync_nacks = 0
        self.sync_bytes = 0.0
        self.sync_bytes_full = 0.0
        # per-message-class fabric meters ({cls: {msgs, bytes, dropped,
        # retries}}), filled from NetworkFabric.class_stats() at end of run
        self.net_stats: dict[str, dict] = {}

    # -- output path --------------------------------------------------------
    def emit(self, t: float, partition: int, window: int, value) -> bool:
        """Returns True if this was a new (non-duplicate) output."""
        key = (partition, window)
        if key in self.records:
            self.records[key].duplicates += 1
            self.duplicates += 1
            if self.obs is not None and self.obs.on:
                self.obs.registry.counter("windows_duplicate").inc()
            return False
        close_ts = self._close_ts(window)
        lag = max(0.0, t - close_ts)
        self.records[key] = WindowRecord(
            partition=partition,
            window=window,
            value=value,
            emit_time=t,
            latency=lag,
        )
        if self.obs is not None and self.obs.on:
            self.obs.registry.counter("windows_emitted").inc()
            self.obs.registry.histogram("phase_ms", phase="emit").observe(lag)
        return True

    def count_events(self, t: float, n: int) -> None:
        self.events_consumed.append((t, n))

    def _close_ts(self, window: int) -> float:
        """Event-time close of a window — the latency zero point."""
        if self.assigner is not None:
            return float(self.assigner.end_ts(window))
        return (window + 1) * self.window_len

    # -- metrics -------------------------------------------------------------
    def latencies(self) -> np.ndarray:
        return np.array([r.latency for r in self.records.values()], dtype=np.float64)

    def latency_series(self) -> tuple[np.ndarray, np.ndarray]:
        """(window close time, latency) sorted by time — Fig 6 style."""
        recs = sorted(self.records.values(), key=lambda r: (r.window, r.partition))
        t = np.array([self._close_ts(r.window) for r in recs])
        lat = np.array([r.latency for r in recs])
        return t, lat

    def latency_stats(self) -> dict[str, float]:
        # the one shared summary implementation (repro.obs.registry.summary):
        # benchmark rows, the auditor, and this probe all agree on percentiles
        return summary(self.latencies())

    def throughput_series(self, bucket_ms: float = 1000.0) -> tuple[np.ndarray, np.ndarray]:
        if not self.events_consumed:
            return np.array([]), np.array([])
        ts = np.array([t for t, _ in self.events_consumed])
        ns = np.array([n for _, n in self.events_consumed], dtype=np.float64)
        t_end = ts.max() + bucket_ms
        edges = np.arange(0.0, t_end + bucket_ms, bucket_ms)
        idx = np.digitize(ts, edges) - 1
        out = np.zeros(len(edges) - 1)
        np.add.at(out, idx, ns)
        return edges[:-1], out / (bucket_ms / 1000.0)  # events/sec

    def sensitivity(self, baseline: "Consumer") -> float:
        """Paper §5.1 (Stabl [19]): area between the latency curve under
        failures and the failure-free baseline curve, per common window."""
        base = {k: r.latency for k, r in baseline.records.items()}
        delta = 0.0
        for k, r in self.records.items():
            if k in base:
                delta += max(0.0, r.latency - base[k]) * 1e-3  # ms * window -> s
        return delta
