"""Holon Streaming runtime — Algorithm 2 with decentralized coordination,
driven by the discrete-event simulator.

Every node runs four independent loops (no global coordination anywhere):

  executor   : round-robin over owned partitions; read next available input
               batch; fold it into the node replica (real JAX dataplane);
               emit every newly-completed window (gated by the global
               watermark, so emissions are deterministic and idempotent).
  sync       : every ``sync_interval`` publish a per-peer *delta*
               (``delta_since`` the peer's acked baseline) on the broadcast
               stream; peers lattice-join it on delivery.  A peer applies a
               delta only when its replica dominates the delta's baseline
               (the causal delta-merging condition) and acks the sender's
               marker; otherwise it nacks, the sender drops the baseline,
               and the next round ships the full resident state.  With
               ``cfg.delta_sync=False`` the loop broadcasts whole replicas
               (the paper's original protocol, kept for comparison).
               *Which* peers a round contacts is the pluggable dissemination
               topology (``cfg.topology``, runtime/topology.py): the
               all-to-all oracle, or a sparse graph — rotating k-ring,
               hypercube, seeded partial view — whose multi-hop relay keeps
               outputs byte-identical at sub-quadratic per-round traffic
               (docs/protocol.md §5).
               Message-sequence walkthrough: docs/protocol.md §2.
  checkpoint : every ``ckpt_interval`` put each owned partition's
               (nxt_idx, nxt_odx, emitted_upto, replica, local) to storage —
               unsynchronized, local decision ("sometimes do").  Snapshots
               carry their delta-coverage baseline and the membership epoch.
  control    : heartbeat peers (beacons carry the membership epoch; a
               ``leaving`` beacon announces graceful departure; under a
               sparse topology they also piggyback a bounded liveness
               digest, so sightings spread transitively — docs/protocol.md
               §5); on silence > ``hb_timeout`` — or on a leaving beacon —
               recompute the deterministic rendezvous assignment over the
               live membership and *steal* orphaned partitions by fetching
               their checkpoints (Recover).  Walkthrough: docs/protocol.md §3.

Membership is fully dynamic: ``HolonHarness.reconfigure(add=…, remove=…)`` is
the operator control-plane event.  New nodes bootstrap by requesting a
full-state sync from the first live peer they hear (docs/protocol.md §3.1);
removed nodes drain — final delta flush, fresh per-partition handoff
checkpoints, then a leaving beacon (docs/protocol.md §3.2) — so planned
scale-in pays no replay, unlike a crash.  Partition placement is rendezvous
hashing over the live view: any two converged views agree on every owner,
and membership churn only moves the partitions it must.

Failure injection flips ``alive``; restart wipes volatile state and rejoins —
recovery is work stealing like any other reconfiguration (paper §4.3).
Exactly-once: deterministic replay from checkpoints + consumer dedup by
(partition, window); property-tested against a failure-free oracle.

Every message above rides the :class:`~repro.runtime.net.NetworkFabric`
(docs/protocol.md §4): gossip (heartbeats, sync deltas, acks/nacks) on the
lossy fire-and-forget tier — convergence only needs at-least-once *eventual*
delivery, because a lost delta is subsumed by the next round's
delta-since-unmoved-baseline — while checkpoint put/get and the joiner's
state request use retried request-response over idempotent handlers.
Scenarios can partition, heal, and degrade links (``Scenario.partition`` /
``heal`` / ``degrade``); with the default lossless zero-jitter profile the
fabric reproduces the pre-fabric event schedule bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import wcrdt as W
from repro.obs.telemetry import Telemetry
from repro.runtime.config import FailureScenario, Scenario, SimConfig, as_scenario
from repro.runtime.consumer import Consumer
from repro.runtime.net import (
    CTRL_BYTES,
    GOSSIP_ENTRY_BYTES,
    HB_BYTES,
    STORAGE,
    NetworkFabric,
)
from repro.runtime.sim import Sim
from repro.runtime.topology import topology_from_spec
from repro.runtime.storage import CheckpointStorage, PartitionCheckpoint
from repro.streaming.events import EventBatch
from repro.streaming.generator import NexmarkConfig, generate_log
from repro.streaming.queries import Query

_M64 = (1 << 64) - 1


def _hrw_score(pid: int, nid: int) -> int:
    """Deterministic 64-bit mix of (partition, node) — splitmix64 finalizer,
    so placement is identical across processes (no Python hash salt)."""
    x = (pid * 0x9E3779B97F4A7C15 + (nid + 1) * 0xBF58476D1CE4E5B9) & _M64
    x ^= x >> 30
    x = (x * 0xBF58476D1CE4E5B9) & _M64
    x ^= x >> 27
    x = (x * 0x94D049BB133111EB) & _M64
    return x ^ (x >> 31)


def assignment(pid: int, live_nodes: Iterable[int]) -> int:
    """Deterministic partition→node rule over the live membership set:
    rendezvous (highest-random-weight) hashing.  Two nodes with converged
    views agree on every owner, and a membership change moves only the
    partitions whose winner joined or left (tests/test_reconfig.py)."""
    best, best_score = -1, -1
    for n in live_nodes:
        s = _hrw_score(pid, n)
        if s > best_score or (s == best_score and n > best):
            best, best_score = n, s
    return best


@dataclasses.dataclass
class PartitionMeta:
    idx: int = 0  # next input-log batch index
    odx: int = 0  # next output index
    emitted_upto: int = 0  # first window id not yet emitted


class HolonNode:
    def __init__(self, nid: int, harness: "HolonHarness"):
        self.nid = nid
        self.h = harness
        self.alive = True
        self.owned: list[int] = []
        self.meta: dict[int, PartitionMeta] = {}
        self.locals: dict[int, Any] = {}
        self.replica = harness.query.init_shared()
        self.last_hb: dict[int, float] = {}
        self._rr = 0  # round-robin cursor over owned partitions
        self.generation = 0  # bumped on restart; stale callbacks check it
        # delta sync: per-peer acked (folded, progress) baseline per shared
        # spec — what the peer is known to hold; absent = ship full state
        self.peer_baseline: dict[int, tuple] = {}
        self._baseline_t: dict[int, float] = {}  # last ack time per peer
        # dynamic membership (docs/protocol.md §3)
        self.epoch = 0  # highest membership epoch seen (gossiped in beacons)
        self.departing = False  # set while draining for scale-in
        self._bootstrap_pending = False  # joiner: request state on first hb
        # graceful departures seen (nid -> leaving-beacon time): guards
        # transitive liveness gossip against resurrecting a drained peer
        # from a stale relayed sighting (docs/protocol.md §5); a sighting
        # newer than the departure (scale-out revival) clears the entry
        self.departed: dict[int, float] = {}
        # subscription-versioned peer-list cache (rebuilding the full list
        # per beacon/sync round is O(N) x every round x every node)
        self._peers_cache: tuple | None = None

    # ---- lifecycle ---------------------------------------------------------
    def boot(self, initial_pids: list[int]):
        obs = self.h.obs
        if obs.on:
            obs.event(
                "node.boot", node=self.nid,
                status="joiner" if self._bootstrap_pending else "member",
                pids=tuple(sorted(initial_pids)), epoch=self.epoch,
            )
        for pid in sorted(initial_pids):
            self._adopt(pid, ckpt=None)
        sim = self.h.sim
        gen = self.generation
        sim.after(0.0, lambda: self._loop_exec(gen))
        sim.after(self.h.cfg.sync_interval_ms, lambda: self._loop_sync(gen))
        sim.after(self.h.cfg.hb_interval_ms, lambda: self._loop_control(gen))
        sim.after(self.h.cfg.ckpt_interval_ms, lambda: self._loop_ckpt(gen))
        self._broadcast_hb()

    def fail(self):
        if self.h.obs.on:
            # the owned-partition snapshot is what the auditor's
            # recovery-bound invariant checks adoption against
            self.h.obs.event("node.crash", node=self.nid, owned=tuple(self.owned))
        self.alive = False

    def restart(self):
        """Rejoin with empty volatile state; recover owned work from storage."""
        if self.h.obs.on:
            self.h.obs.event("node.restart", node=self.nid,
                             generation=self.generation + 1)
        self.generation += 1
        self.alive = True
        self.owned = []
        self.meta = {}
        self.locals = {}
        self.replica = self.h.query.init_shared()
        self.last_hb = {}
        self._rr = 0
        self.peer_baseline = {}
        self._baseline_t = {}
        self.departing = False
        self._bootstrap_pending = False
        self.departed = {}
        self.h._subscribe(self.nid)  # rejoin the broadcast stream
        self.boot([])
        # control loop will steal this node's assigned partitions

    def drain(self):
        """Graceful scale-in (docs/protocol.md §3.2): flush a final delta to
        every peer, write fresh handoff checkpoints for every owned
        partition, announce departure, leave.  The flush is scheduled before
        the leaving beacon, and the simulator delivers FIFO per timestamp,
        so peers rebalance only after our state is on the wire — takeover
        reads a checkpoint at the exact input frontier (no replay).

        The flush and the leaving beacon go to *every* subscribed peer even
        under a sparse topology (docs/protocol.md §5): departure is a rare
        one-shot control event, and telling everyone directly is what lets
        peers drop our baselines and rebalance without waiting for the
        gossip graph to carry the news."""
        if not self.alive or self.departing:
            return
        if self.h.obs.on:
            self.h.obs.event("node.drain", node=self.nid, owned=tuple(self.owned))
        self.departing = True
        self._publish_sync(flush=True)
        for pid in list(self.owned):
            self._handoff(pid)
        self._broadcast_hb(leaving=True)
        self.h._unsubscribe(self.nid)  # close our broadcast subscription
        self.alive = False

    # ---- helpers -----------------------------------------------------------
    def _adopt(self, pid: int, ckpt: PartitionCheckpoint | None):
        if pid in self.meta:
            return
        q = self.h.query
        if ckpt is None:
            self.meta[pid] = PartitionMeta()
            self.locals[pid] = q.init_local()
        else:
            self.meta[pid] = PartitionMeta(ckpt.nxt_idx, ckpt.nxt_odx, ckpt.emitted_upto)
            self.locals[pid] = ckpt.local
            if q.shared_specs:
                self.replica = self.h.merge_fn(self.replica, ckpt.shared)
        self.owned = sorted(set(self.owned) | {pid})
        if self.h.obs.on:
            self.h.obs.registry.gauge("owned_partitions", node=self.nid).set(
                len(self.owned)
            )

    def _drop(self, pid: int):
        if pid in self.meta:
            self.owned.remove(pid)
            del self.meta[pid]
            del self.locals[pid]
            if self.h.obs.on:
                self.h.obs.registry.gauge("owned_partitions", node=self.nid).set(
                    len(self.owned)
                )

    def _put_ckpt(self, pid: int, ck: PartitionCheckpoint):
        """Ship one checkpoint over the retried storage tier, recording the
        node-side request (the storage side records ``ckpt.apply`` with the
        frontier that actually stuck — docs/observability.md §2)."""
        if self.h.obs.on:
            self.h.obs.event(
                "ckpt.put", node=self.nid, partition=pid, nxt_idx=ck.nxt_idx,
                emitted_upto=ck.emitted_upto, epoch=ck.epoch,
            )
        self.h.net.rpc(
            self.nid, STORAGE, "ckpt_put", self.h.ckpt_nbytes,
            lambda p=pid, c=ck: self.h.storage.put(p, c),
        )

    def _handoff(self, pid: int):
        """Planned ownership release: put a checkpoint at the *current*
        frontier, then drop.  The next owner resumes from nxt_idx instead of
        replaying from the last periodic snapshot — this is what makes
        scale-in / rebalance nearly free relative to crash recovery."""
        m = self.meta[pid]
        if self.h.obs.on:
            self.h.obs.event("part.handoff", node=self.nid, partition=pid,
                             nxt_idx=m.idx)
        self._put_ckpt(pid, self._checkpoint_of(pid, m))
        self._drop(pid)

    def _checkpoint_of(self, pid: int, m: PartitionMeta) -> PartitionCheckpoint:
        return PartitionCheckpoint(
            nxt_idx=m.idx,
            nxt_odx=m.odx,
            emitted_upto=m.emitted_upto,
            shared=self.replica,
            local=self.locals[pid],
            # coverage marker of the shared snapshot: recovery knows
            # exactly which deltas the checkpoint subsumes, and peers'
            # domination checks replay deterministically from it
            baseline=self.h.marker_of(self.replica),
            epoch=self.epoch,
        )

    def _live_view(self) -> list[int]:
        now = self.h.sim.now
        live = [self.nid]
        for nid, t in self.last_hb.items():
            if now - t <= self.h.cfg.hb_timeout_ms:
                live.append(nid)
        return sorted(set(live))

    def _peers(self) -> list["HolonNode"]:
        """Everyone else still subscribed to the broadcast stream (drained
        nodes closed their subscription, so nobody pays to publish to them —
        restart/scale_out re-subscribes).  Cached against the harness's
        subscription version: the list only changes on node registration,
        drain/decommission, or restart, so the per-round rebuild collapses
        to a version check (verified byte-identical pre/post)."""
        cache = self._peers_cache
        ver = self.h._sub_version
        if cache is None or cache[0] != ver:
            nodes = [
                n
                for n in self.h.nodes.values()
                if n.nid != self.nid and n.nid not in self.h.unsubscribed
            ]
            cache = (ver, nodes, [n.nid for n in nodes])
            self._peers_cache = cache
        return cache[1]

    def _peer_nids(self) -> list[int]:
        self._peers()  # refresh the versioned cache
        return self._peers_cache[2]

    # bounded liveness digest piggybacked on sparse-topology beacons: big
    # enough to flood fresh sightings in O(log N) rounds, small enough to
    # keep heartbeats O(1) — docs/protocol.md §5
    GOSSIP_DIGEST = 16

    def _gossip_digest(self) -> tuple:
        """The freshest sightings we hold, newest first (nid tie-break), so
        a relayed entry always carries the *original* beacon send-time —
        transitive liveness never claims more than a direct beacon did."""
        items = sorted(self.last_hb.items(), key=lambda kv: (-kv[1], kv[0]))
        return tuple(items[: self.GOSSIP_DIGEST])

    def _broadcast_hb(self, leaving: bool = False):
        if not self.alive and not leaving:
            return
        t, ep, joining = self.h.sim.now, self.epoch, self._bootstrap_pending
        topo = self.h.topology
        peers = self._peers()
        if leaving or not topo.sparse:
            # all-to-all, and every leaving beacon: direct to everyone,
            # no digest (transitive gossip is provably redundant when each
            # beacon already reaches each peer — docs/protocol.md §5)
            targets, view, gone = peers, (), ()
        else:
            rnd = int(t // max(self.h.cfg.hb_interval_ms, 1.0))
            sel = set(topo.peers_of(self.nid, rnd, self._peer_nids()))
            targets = [p for p in peers if p.nid in sel]
            view = self._gossip_digest()
            gone = tuple(
                sorted(self.departed.items(), key=lambda kv: (-kv[1], kv[0]))
                [: self.GOSSIP_DIGEST]
            )
        nbytes = HB_BYTES + GOSSIP_ENTRY_BYTES * (len(view) + len(gone))
        for other in targets:
            self.h.net.send(
                self.nid, other.nid, "hb", nbytes,
                lambda o=other, s=self.nid, tt=t, e=ep, lv=leaving, jn=joining,
                       vw=view, gn=gone:
                    o._on_hb(s, tt, e, lv, jn, vw, gn),
            )

    def _note_sighting(self, nid: int, t: float):
        """Record a liveness sighting (direct beacon or relayed digest
        entry), guarded against stale news about a departed peer: only a
        sighting strictly newer than the departure revives it (that is a
        scale-out re-join, whose fresh beacons postdate the drain)."""
        dep = self.departed.get(nid)
        if dep is not None:
            if t <= dep:
                return
            del self.departed[nid]
        cur = self.last_hb.get(nid, -1.0)
        if t > cur:
            self.last_hb[nid] = t

    def _note_departed(self, nid: int, t: float):
        """Record a graceful departure (direct leaving beacon or relayed
        entry).  Ignored when we have already seen the peer alive *after*
        ``t`` — the departure news is stale and the peer is back."""
        if self.last_hb.get(nid, -1.0) > t:
            return
        self.departed[nid] = max(self.departed.get(nid, -1.0), t)
        known = self.last_hb.pop(nid, None) is not None
        self.peer_baseline.pop(nid, None)
        self._baseline_t.pop(nid, None)
        if known:
            # newly learned departure via gossip: rebalance like a direct
            # leaving beacon would have (docs/protocol.md §3.2)
            self._rebalance(self.generation)

    def _on_hb(self, sender: int, t: float, epoch: int, leaving: bool,
               joining: bool = False, view: tuple = (), gone: tuple = ()):
        if not self.alive:
            return
        self.epoch = max(self.epoch, epoch)
        if leaving:
            # graceful departure: drop the peer from the live view *now*
            # (no hb_timeout wait) and take over its partitions promptly
            self.departed[sender] = max(self.departed.get(sender, -1.0), t)
            self.last_hb.pop(sender, None)
            self.peer_baseline.pop(sender, None)
            self._baseline_t.pop(sender, None)
            self._rebalance(self.generation)
            return
        self._note_sighting(sender, t)
        for nid, tn in view:
            if nid != self.nid:
                self._note_sighting(nid, tn)
        for nid, tn in gone:
            if nid != self.nid:
                self._note_departed(nid, tn)
        if self._bootstrap_pending and not joining:
            # joiner bootstrap (docs/protocol.md §3.1): ask the first
            # *settled* peer we hear for its full state (a co-joiner's beacon
            # carries joining=True — its empty replica would waste the
            # one-shot handshake); the request rides the fabric's reliable
            # tier (docs/protocol.md §4) and the reply the ordinary sync
            # path with no baseline, so it merges unconditionally — a lost
            # reply is absorbed because our unseeded baseline makes the
            # server's next delta round ship its full resident state
            self._bootstrap_pending = False
            self.h.net.send_reliable(
                self.nid, sender, "state_req", CTRL_BYTES,
                lambda s=sender, me=self.nid:
                    self.h.nodes[s]._on_state_request(me),
            )

    # ---- loops ---------------------------------------------------------------
    def _loop_exec(self, gen: int):
        if not self.alive or gen != self.generation:
            return
        cfg = self.h.cfg
        delay = cfg.poll_idle_ms
        if self.owned:
            # round-robin over owned partitions ("sometimes do" in Alg. 2 —
            # deterministic for reproducibility)
            for _ in range(len(self.owned)):
                pid = self.owned[self._rr % len(self.owned)]
                self._rr += 1
                cost = self._try_process(pid)
                if cost is not None:
                    delay = cost
                    break
        self.h.sim.after(delay, lambda: self._loop_exec(gen))

    def _try_process(self, pid: int) -> float | None:
        """Fold the next available batch; returns its processing cost in ms
        (scaled by the batch's valid-event fraction, so skewed loads cost
        what they carry), or None when nothing was processed."""
        cfg, q = self.h.cfg, self.h.query
        m = self.meta[pid]
        if m.idx >= cfg.num_batches:
            self._emit_ready(pid)  # drain tail windows as gwm advances
            return None
        # batch b becomes available once the producer has written it
        avail = (m.idx + 1) * cfg.batch_span_ms
        if self.h.sim.now < avail:
            self._emit_ready(pid)
            return None
        batch = self.h.batch(pid, m.idx)
        frac = float(self.h.valid_frac[pid, m.idx])
        self.replica, self.locals[pid] = self.h.fold_fn(
            self.replica, self.locals[pid], batch, pid, m.idx
        )
        m.idx += 1
        n_events = int(round(frac * cfg.events_per_batch))
        self.h.consumer.count_events(self.h.sim.now, n_events)
        cost = max(cfg.batch_proc_ms * frac, cfg.batch_proc_ms / cfg.events_per_batch)
        obs = self.h.obs
        if obs.on:
            now = self.h.sim.now
            queue_ms = now - avail  # batch availability -> dequeue
            obs.event(
                "exec.batch", node=self.nid, partition=pid, status="ok",
                t_end_ms=now + cost, idx=m.idx - 1, queue_ms=queue_ms,
                # the batch watermark this fold raised progress[pid] to —
                # the provenance critical-path analysis replays the global
                # watermark lattice from (obs/critpath.py)
                wm=int(self.h.batch_wm[pid, m.idx - 1]),
            )
            reg = obs.registry
            reg.counter("batches_folded", node=self.nid).inc()
            reg.counter("events_folded", node=self.nid).inc(n_events)
            reg.histogram("phase_ms", phase="queue").observe(queue_ms)
            reg.histogram("phase_ms", phase="process").observe(cost)
        self._emit_ready(pid)
        return cost

    def _emit_ready(self, pid: int):
        """Emit every window completed under the current global watermark.

        Iterates assigner-complete windows (``gwm >= end_ts(wid)``): window
        ends are monotone in wid for any assigner, so completeness is
        prefix-closed and ``emitted_upto`` advances exactly as for tumbling
        — overlapping windows emit in wid order, each deduplicated by the
        consumer under crash/restart/reconfigure like any other window."""
        q = self.h.query
        m = self.meta[pid]
        gwm = int(q.global_watermark(self.replica, self.locals[pid]))
        obs = self.h.obs
        while q.assigner.complete(m.emitted_upto, gwm):
            wid = m.emitted_upto
            val, ok = self.h.read_fn(self.replica, self.locals[pid], wid)
            if not bool(ok):
                # complete but no longer ring-resident (emission lagged more
                # than num_slots windows) — skip and count; sized-away in cfg
                self.h.evicted_windows += 1
                if obs.on:
                    obs.event("emit", node=self.nid, partition=pid, window=wid,
                              status="evicted")
                m.emitted_upto = wid + 1
                continue
            arr = np.asarray(val)
            fresh = self.h.consumer.emit(self.h.sim.now, pid, wid, arr)
            if obs.on:
                # digest lets the auditor tell benign duplicates (same value,
                # exactly-once by dedup) from genuine divergence
                obs.event(
                    "emit", node=self.nid, partition=pid, window=wid,
                    status="accepted" if fresh else "duplicate",
                    latency_ms=max(
                        0.0, self.h.sim.now - float(q.assigner.end_ts(wid))
                    ),
                    digest=zlib.crc32(arr.tobytes()),
                )
            m.odx += 1
            m.emitted_upto = wid + 1

    def _loop_sync(self, gen: int):
        if not self.alive or gen != self.generation:
            return
        self._publish_sync()
        self.h.sim.after(self.h.cfg.sync_interval_ms, lambda: self._loop_sync(gen))

    def _publish_sync(self, flush: bool = False):
        """One background sync round: a delta (or full replica) to each peer
        the dissemination topology schedules for this round — every peer
        under the all-to-all oracle, a sparse subset otherwise; multi-hop
        relay through intermediate replicas carries the rest
        (docs/protocol.md §5).  ``flush=True`` (drain) bypasses the
        topology and contacts everyone one last time.

        Identical baselines ship identical deltas, so the (deterministic)
        ``delta_fn`` runs once per *distinct* baseline, not once per peer —
        in the converged steady state that is one call per round."""
        if not self.h.query.shared_specs:
            return
        snap = self.replica
        marker = self.h.marker_of(snap)
        peers = self._peers()
        self.h.note_counterfactual_round(len(peers))
        topo = self.h.topology
        if flush or not topo.sparse:
            targets = peers
        else:
            rnd = int(self.h.sim.now // max(self.h.cfg.sync_interval_ms, 1.0))
            sel = set(topo.peers_of(self.nid, rnd, self._peer_nids()))
            targets = [p for p in peers if p.nid in sel]
        shipped_total = 0.0
        if self.h.cfg.delta_sync:
            ttl = self.h.cfg.baseline_ttl_ms
            if ttl > 0.0:
                self._age_baselines(ttl)
            by_base: dict = {}
            for other in targets:
                base = self.peer_baseline.get(other.nid, self.h.zero_base)
                key = tuple((bf.tobytes(), bp.tobytes()) for bf, bp in base)
                ent = by_base.get(key)
                if ent is None:
                    payload = self.h.delta_fn(snap, base)
                    ent = by_base[key] = (base, payload, self.h.delta_bytes(payload))
                base, payload, shipped = ent
                shipped_total += shipped
                self.h.net.send(
                    self.nid, other.nid, "sync", shipped,
                    lambda o=other, pay=payload, b=base, mk=marker: o._on_sync(
                        pay, self.nid, b, mk
                    ),
                )
        else:
            for other in targets:
                shipped_total += self.h.full_state_bytes
                self.h.net.send(
                    self.nid, other.nid, "sync", self.h.full_state_bytes,
                    lambda o=other, mk=marker: o._on_sync(
                        snap, self.nid, None, mk
                    ),
                )
        obs = self.h.obs
        if obs.on and targets:
            obs.event(
                "sync.publish", node=self.nid,
                status="delta" if self.h.cfg.delta_sync else "full",
                peers=tuple(o.nid for o in targets), shipped=shipped_total,
                topology=topo.name, fanout=len(targets),
            )
            obs.registry.counter("sync_rounds", node=self.nid).inc()

    def _age_baselines(self, ttl_ms: float):
        """Drop ack baselines not refreshed within ``ttl_ms``: the peer
        falls back to ``zero_base`` (one full-state round re-seeds it).
        Baselines are always *valid* — a peer acked what it holds and
        replicas only grow — so aging bounds staleness and memory under
        sparse fanout, never correctness (docs/protocol.md §5)."""
        cut = self.h.sim.now - ttl_ms
        for nid in [n for n, t in self._baseline_t.items() if t < cut]:
            del self._baseline_t[nid]
            self.peer_baseline.pop(nid, None)

    def _on_state_request(self, requester: int):
        """Serve a joiner's bootstrap: reply with the full replica and its
        marker, no baseline — the joiner merges unconditionally and acks,
        which also seeds our delta baseline for it.  The fabric meters the
        reply's real bytes; it deliberately does NOT count toward
        ``sync_bytes_full``, which models only periodic sync rounds."""
        if not self.alive or not self.h.query.shared_specs:
            return
        snap = self.replica
        marker = self.h.marker_of(snap)
        self.h.bootstrap_served.append((requester, self.nid))
        if self.h.obs.on:
            self.h.obs.event("sync.bootstrap", node=self.nid, dst=requester,
                             shipped=self.h.full_state_bytes)
        self.h.net.send(
            self.nid, requester, "sync", self.h.full_state_bytes,
            lambda r=requester, s=snap, mk=marker: self.h.nodes[r]._on_sync(
                s, self.nid, None, mk
            ),
        )

    def _on_sync(self, snap, src: int | None = None, base=None, marker=None):
        if not self.alive:
            return
        obs = self.h.obs
        if base is not None and not self._dominates(base):
            # our replica (e.g. freshly recovered from an older checkpoint)
            # does not cover the delta's baseline — applying it would lose
            # the gap.  Nack so the sender resets to a full-state round.
            self.h.sync_nacks += 1
            if obs.on:
                obs.event("sync.recv", node=self.nid, src=src, status="nack",
                          dominated=0)
                obs.registry.counter("sync_nacks", node=self.nid).inc()
            if src is not None:
                self.h.net.send(
                    self.nid, src, "sync_nack", CTRL_BYTES,
                    lambda s=src: self.h.nodes[s]._on_sync_nack(self.nid),
                )
            return
        self.replica = self.h.merge_fn(self.replica, snap)
        if obs.on:
            # recorded before the emit sweep so merge-then-emit causality
            # reads in order; marker=1 iff an ack will go back this instant
            obs.event(
                "sync.recv", node=self.nid, src=src,
                status="delta_merge" if base is not None else "full_merge",
                dominated=1, marker=1 if marker is not None and src is not None else 0,
            )
        # merged watermark may complete windows for our partitions
        for pid in self.owned:
            self._emit_ready(pid)
        if marker is not None and src is not None:
            self.h.net.send(
                self.nid, src, "sync_ack", self.h.marker_nbytes,
                lambda s=src, mk=marker: self.h.nodes[s]._on_sync_ack(self.nid, mk),
            )

    def _dominates(self, base) -> bool:
        """Causal delta-merging condition: do we already hold everything the
        sender assumed (per-spec folded & progress at or past the baseline)?"""
        for st, (bf, bp) in zip(self.replica, base):
            if np.any(np.asarray(st.folded) < bf) or np.any(np.asarray(st.progress) < bp):
                return False
        return True

    def _on_sync_ack(self, peer: int, marker):
        if not self.alive:
            return
        self._baseline_t[peer] = self.h.sim.now  # refresh the aging clock
        cur = self.peer_baseline.get(peer)
        if cur is None:
            self.peer_baseline[peer] = marker
        else:  # acks may arrive out of order; the baseline only grows
            self.peer_baseline[peer] = tuple(
                (np.maximum(cf, mf), np.maximum(cp, mp))
                for (cf, cp), (mf, mp) in zip(cur, marker)
            )

    def _on_sync_nack(self, peer: int):
        if self.alive:
            self.peer_baseline.pop(peer, None)
            self._baseline_t.pop(peer, None)

    def _loop_control(self, gen: int):
        if not self.alive or gen != self.generation:
            return
        self._broadcast_hb()
        self._rebalance(gen)
        self.h.sim.after(self.h.cfg.hb_interval_ms, lambda: self._loop_control(gen))

    def _rebalance(self, gen: int):
        """Steal partitions the rendezvous rule assigns to me that I don't
        own; hand off ones whose owner is now someone else.  A joiner still
        bootstrapping skips the sweep: under sparse dissemination its live
        view is one or two beacons old, and rendezvous over that sliver
        would steal partitions it must immediately hand back — the next
        control tick (post-bootstrap, view converging) rebalances for real."""
        if not self.alive or gen != self.generation or self._bootstrap_pending:
            return
        live = self._live_view()
        owners = self.h.owners_of(tuple(live))
        for pid, tgt in enumerate(owners):
            if tgt == self.nid and pid not in self.meta:
                # steal handshake, then a fabric-routed checkpoint fetch:
                # _finish_steal runs at the RPC's round-trip point (and
                # re-checks the assignment under the then-current view)
                self.h.sim.after(
                    self.h.cfg.steal_delay_ms,
                    lambda p=pid, g=gen: self.h.net.rpc(
                        self.nid, STORAGE, "ckpt_get", CTRL_BYTES,
                        lambda p=p, g=g: self._finish_steal(p, g),
                    ),
                )
            elif tgt != self.nid and pid in self.meta:
                self._handoff(pid)

    def _finish_steal(self, pid: int, gen: int):
        if not self.alive or gen != self.generation or pid in self.meta:
            return
        # re-check assignment under the current view (node may have returned)
        if self.h.owners_of(tuple(self._live_view()))[pid] != self.nid:
            return
        ck = self.h.storage.get(pid)
        if self.h.obs.on:
            self.h.obs.event(
                "steal.adopt", node=self.nid, partition=pid,
                status="ckpt" if ck is not None else "fresh",
                nxt_idx=ck.nxt_idx if ck is not None else 0,
            )
        self._adopt(pid, ck)

    def _loop_ckpt(self, gen: int):
        if not self.alive or gen != self.generation:
            return
        for pid in list(self.owned):
            # async durable write completes after one storage RTT; the RPC
            # tier re-issues lost legs (merge-on-put is idempotent)
            self._put_ckpt(pid, self._checkpoint_of(pid, self.meta[pid]))
        self.h.sim.after(self.h.cfg.ckpt_interval_ms, lambda: self._loop_ckpt(gen))


class HolonHarness:
    def __init__(self, cfg: SimConfig, query: Query, log: EventBatch | None = None):
        self.cfg = cfg
        self.query = query
        nx = NexmarkConfig(
            num_partitions=cfg.num_partitions,
            num_batches=cfg.num_batches,
            events_per_batch=cfg.events_per_batch,
            rate_per_partition=cfg.rate_per_partition,
            seed=cfg.seed,
            skew=cfg.skew,
        )
        self.log = log if log is not None else generate_log(nx)
        self._log_np = jax.tree.map(np.asarray, self.log)
        # per-(partition, batch) valid-event fraction: drives the modeled
        # processing cost, so load skew translates into node load
        self.valid_frac = np.asarray(self._log_np.valid, np.float64).mean(axis=-1)
        # per-(partition, batch) watermark — host mirror of the dataplane's
        # batch_watermark(), recorded on exec.batch spans so the critical-
        # path analyzer (obs/critpath.py) can replay the progress lattice
        # exactly; pure derived data, so it cannot perturb the run
        self.batch_wm = np.where(
            np.asarray(self._log_np.valid),
            np.asarray(self._log_np.ts, np.int64), -(2 ** 31)
        ).max(axis=-1)
        self.sim = Sim()
        # one telemetry hub per run (docs/observability.md): the fabric,
        # storage, consumer, and every node record into the same bounded
        # ring + registry, so exported traces interleave in causal order
        self.obs = Telemetry.from_config(self.sim, cfg)
        # all inter-node and node<->storage delivery rides the fabric
        # (runtime/net.py, docs/protocol.md §4); the default profile is the
        # perfect wire, so fabric-off is not a mode — lossless IS the fabric
        self.net = NetworkFabric.from_config(self.sim, cfg, telemetry=self.obs)
        self.storage = CheckpointStorage(telemetry=self.obs)
        self.consumer = Consumer(
            window_len=cfg.window_len, assigner=query.assigner, telemetry=self.obs
        )
        self.evicted_windows = 0
        # jitted dataplane
        self.fold_fn = jax.jit(query.fold)
        self.merge_fn = jax.jit(query.merge_shared)
        self.read_fn = jax.jit(query.read)
        # delta-sync dataplane + sync-bandwidth accounting
        specs = query.shared_specs
        self.delta_fn = jax.jit(
            lambda snap, base: tuple(
                W.delta_since(spec, st, bf, bp)
                for spec, st, (bf, bp) in zip(specs, snap, base)
            )
        )
        self.zero_base = tuple(W.zero_baseline(spec) for spec in specs)
        self.full_state_bytes = float(
            sum(W.state_nbytes(st) for st in query.init_shared())
        )
        # wire sizes for messages the fabric meters: a sync ack carries the
        # (folded, progress) marker; a checkpoint ships the replica snapshot
        # plus the partition's local state and cursors
        self.marker_nbytes = float(sum(f.nbytes + p.nbytes for f, p in self.zero_base))
        loc = query.init_local()
        self.ckpt_nbytes = self.full_state_bytes + CTRL_BYTES + (
            float(W.state_nbytes(loc)) if loc is not None else 0.0
        )
        self.sync_nacks = 0
        self.sync_bytes_full = 0.0  # what full-state all-to-all would ship
        # dissemination topology of the gossip plane (docs/protocol.md §5):
        # one schedule object shared by every node's sync + heartbeat loops
        self.topology = topology_from_spec(cfg.topology, seed=cfg.seed)
        # dynamic membership: nid -> node, every node ever registered (the
        # broadcast-stream subscriber list); epoch bumps per reconfigure
        self.nodes: dict[int, HolonNode] = {
            n: HolonNode(n, self) for n in cfg.initial_membership
        }
        self.membership_epoch = 0
        # broadcast-stream subscription registry: drained nodes unsubscribe,
        # so publishers stop paying per-peer sync cost for them.  Mutate it
        # only through _subscribe/_unsubscribe — _sub_version invalidates
        # every node's cached peer list
        self.unsubscribed: set[int] = set()
        self._sub_version = 0
        # rendezvous assignment memo: owners of every partition per distinct
        # live view.  assignment() is a pure function, so converged views
        # (the common case — every node, every control tick) share one
        # entry instead of re-hashing num_partitions x live_nodes each tick
        self._assign_cache: dict[tuple[int, ...], tuple[int, ...]] = {}
        # (requester, server) log of §3.1 bootstrap handshakes (test probe)
        self.bootstrap_served: list[tuple[int, int]] = []
        # online protocol monitor (obs/monitor.py): a passive telemetry
        # subscriber — alerts accumulate on self.monitor, the run itself is
        # byte-identical with it on or off (docs/observability.md §6)
        self.monitor = None
        if cfg.obs_monitor:
            from repro.obs.monitor import OnlineMonitor
            self.monitor = OnlineMonitor.from_config(cfg)
            self.monitor.attach(self.obs)

    def _subscribe(self, nid: int) -> None:
        self.unsubscribed.discard(nid)
        self._sub_version += 1

    def _unsubscribe(self, nid: int) -> None:
        self.unsubscribed.add(nid)
        self._sub_version += 1

    def note_counterfactual_round(self, num_peers: int) -> None:
        """Accrue ``sync_bytes_full``: what a full-state **all-to-all**
        broadcast would have shipped for this sync round — always every
        subscribed peer at full state, regardless of the configured
        topology or delta sync.  Bootstrap serves are deliberately NOT
        counted (they are §3.1 membership traffic, metered by the fabric);
        mixing them in here used to overstate the counterfactual and make
        the delta-savings ratio look better than it was."""
        self.sync_bytes_full += self.full_state_bytes * num_peers

    def owners_of(self, live: tuple[int, ...]) -> tuple[int, ...]:
        """``assignment(pid, live)`` for every partition, memoized per live
        view (byte-identical to calling the rule directly)."""
        owners = self._assign_cache.get(live)
        if owners is None:
            if len(self._assign_cache) > 4096:  # churn bound, not a hot path
                self._assign_cache.clear()
            owners = tuple(
                assignment(p, live) for p in range(self.cfg.num_partitions)
            )
            self._assign_cache[live] = owners
        return owners

    # sync bandwidth now comes from the fabric's per-class meters — the
    # single source of truth for wire bytes (docs/protocol.md §4).  "sync"
    # covers delta/full rounds AND bootstrap full-state replies, exactly
    # what the pre-fabric ad-hoc counters summed.
    @property
    def sync_msgs(self) -> int:
        return self.net.msgs_of("sync")

    @property
    def sync_bytes(self) -> float:
        return self.net.bytes_of("sync")

    @staticmethod
    def marker_of(snap) -> tuple:
        """Host-side (folded, progress) coverage marker of a replica tuple."""
        return tuple(
            (np.asarray(st.folded), np.asarray(st.progress)) for st in snap
        )

    @staticmethod
    def delta_bytes(deltas) -> float:
        return float(sum(float(W.delta_nbytes(d)) for d in deltas))

    def batch(self, pid: int, idx: int) -> EventBatch:
        return jax.tree.map(lambda x: x[pid, idx], self.log)

    # ---- control plane --------------------------------------------------------
    def reconfigure(self, add: Iterable[int] = (), remove: Iterable[int] = ()):
        """Operator control-plane event at the current sim time: grow and/or
        shrink the membership.  Added nodes bootstrap from a live peer;
        removed nodes drain (docs/protocol.md §3).  Bumps the membership
        epoch, which gossips through heartbeats into checkpoint markers."""
        add, remove = tuple(add), tuple(remove)
        if not add and not remove:
            return
        self.membership_epoch += 1
        if self.obs.on:
            self.obs.event(
                "ctrl.reconfigure", epoch=self.membership_epoch,
                add=tuple(int(n) for n in add),
                remove=tuple(int(n) for n in remove),
            )
        # the reconfigure command rides the control plane: every live
        # subscriber learns the new epoch with the event (so a drain's
        # leaving beacon below already gossips it) — crashed nodes catch up
        # from peers' beacons if they ever restart
        for node in self.nodes.values():
            if node.alive:
                node.epoch = max(node.epoch, self.membership_epoch)
        for nid in add:
            nid = int(nid)
            node = self.nodes.get(nid)
            if node is None:
                node = HolonNode(nid, self)
                self.nodes[nid] = node
                self._sub_version += 1  # new broadcast-stream subscriber
                node.epoch = self.membership_epoch
                node._bootstrap_pending = bool(self.query.shared_specs)
                node.boot([])
            elif not node.alive:
                node.epoch = max(node.epoch, self.membership_epoch)
                node.restart()
        for nid in remove:
            node = self.nodes.get(int(nid))
            if node is None:
                continue
            if node.alive:
                node.drain()
            else:
                # decommission a crashed node: it cannot drain, but it must
                # stop costing publishers; peers already rebalanced via
                # hb_timeout when it went silent
                self._unsubscribe(int(nid))

    def _node(self, nid: int) -> HolonNode:
        node = self.nodes.get(nid)
        if node is None:
            raise KeyError(
                f"scenario references node {nid}, which was never a member "
                f"(known: {sorted(self.nodes)})"
            )
        return node

    def run(
        self,
        scenario: Scenario | FailureScenario | None = None,
        horizon_ms: float | None = None,
    ):
        scenario = as_scenario(scenario)
        live0 = sorted(self.nodes)
        owners0 = self.owners_of(tuple(live0))
        for n in self.nodes.values():
            # seed membership: initial members boot knowing the t=0 roster
            # (the deployment's config), exactly as if every boot beacon had
            # already landed — under all-to-all this is what the first
            # beacon round establishes anyway (same send-time 0.0, so the
            # schedule is unchanged); under a sparse topology it stops a
            # cold two-beacon view from triggering spurious rendezvous
            # steals in the first control ticks (docs/protocol.md §5)
            for other in live0:
                if other != n.nid:
                    n.last_hb[other] = 0.0
            n.boot([p for p, o in enumerate(owners0) if o == n.nid])
        for ev in scenario.events:
            if ev.kind == "crash":
                for nid in ev.nodes:
                    self.sim.at(ev.t_ms, lambda n=nid: self._node(n).fail())
            elif ev.kind == "restart":
                for nid in ev.nodes:
                    self.sim.at(ev.t_ms, lambda n=nid: self._node(n).restart())
            elif ev.kind == "scale_out":
                self.sim.at(ev.t_ms, lambda ns=ev.nodes: self.reconfigure(add=ns))
            elif ev.kind == "scale_in":
                self.sim.at(ev.t_ms, lambda ns=ev.nodes: self.reconfigure(remove=ns))
            elif ev.kind == "partition":
                self.sim.at(ev.t_ms, lambda gs=ev.groups: self.net.set_partition(*gs))
            elif ev.kind == "heal":
                self.sim.at(ev.t_ms, self.net.heal)
            elif ev.kind == "degrade":
                self.sim.at(
                    ev.t_ms,
                    lambda e=ev: self.net.degrade(
                        e.nodes, loss=e.loss, jitter_ms=e.jitter_ms
                    ),
                )
        horizon = horizon_ms if horizon_ms is not None else self.cfg.horizon_ms + 5000.0
        self.obs.start_snapshots()
        self.sim.run(until=horizon)
        self.obs.buf.flush_spill()
        # expose sync-bandwidth + fabric counters on the consumer (probe)
        self.consumer.sync_msgs = self.sync_msgs
        self.consumer.sync_nacks = self.sync_nacks
        self.consumer.sync_bytes = self.sync_bytes
        self.consumer.sync_bytes_full = self.sync_bytes_full
        self.consumer.net_stats = self.net.class_stats()
        return self.consumer


def run_holon(
    cfg: SimConfig, query: Query, scenario: Scenario | FailureScenario | None = None,
    horizon_ms: float | None = None, log: EventBatch | None = None,
) -> Consumer:
    h = HolonHarness(cfg, query, log=log)
    return h.run(scenario, horizon_ms)
