"""Simulation cost model + failure scenarios.

Constants mirror the paper's experimental setup (§5.1/§5.2) where stated, and
conservative GCP-like values elsewhere.  All times in milliseconds of
simulated time.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SimConfig:
    # --- workload ---
    num_nodes: int = 5
    num_partitions: int = 10
    window_len: int = 1000  # ms, tumbling (Nexmark Q7 uses seconds-scale)
    num_slots: int = 64  # WCRDT ring size
    events_per_batch: int = 1024
    rate_per_partition: float = 10_000.0  # events/s
    num_batches: int = 400  # ~41 s of event time per partition
    seed: int = 0

    # --- node execution ---
    batch_proc_ms: float = 2.0  # fold+emit compute per batch (2vCPU node)
    poll_idle_ms: float = 2.0  # executor re-poll when no batch available

    # --- Holon decentralized coordination (paper §4) ---
    delta_sync: bool = True  # ship delta_since(peer baseline), not replicas
    sync_interval_ms: float = 100.0  # background CRDT broadcast period
    broadcast_delay_ms: float = 5.0  # one-way broadcast-stream latency
    hb_interval_ms: float = 250.0  # decentralized liveness beacon
    hb_timeout_ms: float = 1000.0  # peer declared failed after this silence
    ckpt_interval_ms: float = 1000.0  # "sometimes do storage.put" period
    storage_rtt_ms: float = 50.0  # remote checkpoint read/write RTT
    steal_delay_ms: float = 20.0  # control-plane work-steal handshake

    # --- Flink-like centralized baseline (paper §5.1 config) ---
    flink_hb_interval_ms: float = 4000.0  # paper: 4 s
    flink_hb_timeout_ms: float = 6000.0  # paper: 6 s
    flink_ckpt_interval_ms: float = 5000.0  # paper: 5 s checkpoints
    flink_restart_ms: float = 8000.0  # job restart + state redistribute
    flink_restore_ms: float = 4000.0  # RocksDB restore from remote
    flink_barrier_pause_ms: float = 30.0  # per-checkpoint alignment stall
    flink_tree_fanin: int = 2  # static aggregation tree fan-in
    shuffle_hop_ms: float = 5.0  # per network hop in the agg tree
    flink_spare_slots: bool = False  # spare TaskManager slots for failover

    @property
    def batch_span_ms(self) -> float:
        return 1000.0 * self.events_per_batch / self.rate_per_partition

    @property
    def horizon_ms(self) -> float:
        return self.num_batches * self.batch_span_ms


@dataclasses.dataclass(frozen=True)
class FailureScenario:
    """When nodes fail and (optionally) restart, in simulated ms.

    The paper's three scenarios (§5.2):
      concurrent: two nodes at t, restart t+10s
      subsequent: two nodes at t, t+5s; each restarts 10s after its failure
      crash:      two nodes at t, never restarted
    """

    name: str = "baseline"
    fail_times_ms: tuple[float, ...] = ()
    fail_nodes: tuple[int, ...] = ()
    restart_times_ms: tuple[float, ...] = ()  # -1 = never

    @classmethod
    def baseline(cls):
        return cls()

    @classmethod
    def concurrent(cls, t: float = 8000.0):
        return cls(
            name="concurrent",
            fail_times_ms=(t, t),
            fail_nodes=(0, 1),
            restart_times_ms=(t + 10_000, t + 10_000),
        )

    @classmethod
    def subsequent(cls, t: float = 8000.0):
        return cls(
            name="subsequent",
            fail_times_ms=(t, t + 5_000),
            fail_nodes=(0, 1),
            restart_times_ms=(t + 10_000, t + 15_000),
        )

    @classmethod
    def crash(cls, t: float = 8000.0):
        return cls(
            name="crash",
            fail_times_ms=(t, t),
            fail_nodes=(0, 1),
            restart_times_ms=(-1.0, -1.0),
        )
