"""Simulation cost model + scenario DSL (failures and elastic reconfiguration).

Constants mirror the paper's experimental setup (§5.1/§5.2) where stated, and
conservative GCP-like values elsewhere.  All times in milliseconds of
simulated time.  Scenarios are sequences of timed control-plane events
(crash / restart / scale_out / scale_in); the membership-change events are
specified in docs/protocol.md §3.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SimConfig:
    # --- workload ---
    num_nodes: int = 5
    num_partitions: int = 10
    window_len: int = 1000  # ms, tumbling (Nexmark Q7 uses seconds-scale)
    num_slots: int = 64  # WCRDT ring size
    events_per_batch: int = 1024
    rate_per_partition: float = 10_000.0  # events/s
    num_batches: int = 400  # ~41 s of event time per partition
    seed: int = 0
    skew: float = 0.0  # zipf exponent of per-partition load (0 = uniform)

    # --- node execution ---
    batch_proc_ms: float = 2.0  # fold+emit compute per batch (2vCPU node)
    poll_idle_ms: float = 2.0  # executor re-poll when no batch available

    # --- Holon decentralized coordination (paper §4) ---
    delta_sync: bool = True  # ship delta_since(peer baseline), not replicas
    sync_interval_ms: float = 100.0  # background CRDT broadcast period
    # dissemination topology of the gossip plane (docs/protocol.md §5):
    # "all" (oracle, O(N^2) msgs/round) | "ring[:k]" | "hypercube" |
    # "partial[:fanout]" — sparse graphs trade propagation hops for
    # sub-quadratic sync traffic, never correctness (runtime/topology.py)
    topology: str = "all"
    # age out per-peer ack baselines not refreshed within this window (an
    # aged-out peer falls back to zero_base, i.e. one full-state round);
    # 0 disables aging — baselines are always *valid*, aging only bounds
    # staleness/memory under sparse fanout (docs/protocol.md §5)
    baseline_ttl_ms: float = 0.0
    broadcast_delay_ms: float = 5.0  # one-way broadcast-stream latency
    hb_interval_ms: float = 250.0  # decentralized liveness beacon
    hb_timeout_ms: float = 1000.0  # peer declared failed after this silence
    ckpt_interval_ms: float = 1000.0  # "sometimes do storage.put" period
    storage_rtt_ms: float = 50.0  # remote checkpoint read/write RTT
    steal_delay_ms: float = 20.0  # control-plane work-steal handshake

    # --- network fabric (runtime/net.py, docs/protocol.md §4) ---
    # Defaults model the perfect wire the runtime always assumed: zero loss,
    # fixed latency (broadcast_delay_ms / storage_rtt_ms above), no reorder —
    # under which the fabric schedules exactly the pre-fabric event sequence.
    net_loss: float = 0.0  # gossip message-loss probability per send
    net_jitter: str = "fixed"  # per-link latency dist: fixed|uniform|lognormal
    net_jitter_ms: float = 0.0  # jitter scale added to the base latency
    net_reorder_prob: float = 0.0  # chance of an extra bounded-reorder delay
    net_reorder_ms: float = 0.0  # size of that extra delay window
    net_seed: int = -1  # fabric RNG seed; -1 reuses the workload seed
    net_rto_ms: float = 200.0  # reliable-tier retransmit timeout
    storage_loss: float = 0.0  # loss on node<->storage RPC legs
    storage_retry_ms: float = 100.0  # RPC re-issue delay after a lost leg
    net_trace: bool = False  # record the per-message delivery trace

    # --- observability (repro/obs, docs/observability.md) ---
    # Off by default: with ``obs=False`` (and ``net_trace=False``) the
    # runtimes make zero telemetry records and stay bit-identical to a
    # build without the obs layer.  ``obs=True`` records protocol
    # spans/events + registry metrics (and implies net records — the
    # auditor's ack cross-check needs them); recording is passive, so
    # same-seed runs export byte-identical traces either way.
    obs: bool = False  # structured span tracing + metrics registry
    obs_trace_cap: int = 1 << 16  # bounded trace ring size (records)
    obs_snapshot_ms: float = 500.0  # registry snapshot period (sim-time)
    # stream ring-evicted records to this JSONL spool instead of dropping
    # them: memory stays bounded at obs_trace_cap while the full stream
    # stays auditable ("" = no spill, evictions count as dropped)
    obs_spill_path: str = ""
    # --- online protocol monitor (obs/monitor.py, docs/observability.md §6) —
    # a passive Telemetry subscriber checking invariants + health signals as
    # records are appended.  Implies ``obs``; monitoring never draws RNG or
    # schedules sim events, so runs stay byte-identical with it on or off.
    obs_monitor: bool = False
    obs_stall_ms: float = 5000.0  # [frontier-stall] alert after this quiet gap
    obs_slo_ms: float = 0.0  # emit-latency SLO; 0 disables [slo-burn]
    obs_slo_frac: float = 0.5  # [slo-burn] when > this frac of recent emits miss
    obs_sync_budget: float = 0.0  # sync bytes/s budget; 0 disables [sync-burn]

    # --- Flink-like centralized baseline (paper §5.1 config) ---
    flink_hb_interval_ms: float = 4000.0  # paper: 4 s
    flink_hb_timeout_ms: float = 6000.0  # paper: 6 s
    flink_ckpt_interval_ms: float = 5000.0  # paper: 5 s checkpoints
    flink_restart_ms: float = 8000.0  # job restart + state redistribute
    flink_restore_ms: float = 4000.0  # RocksDB restore from remote
    flink_barrier_pause_ms: float = 30.0  # per-checkpoint alignment stall
    flink_tree_fanin: int = 2  # static aggregation tree fan-in
    shuffle_hop_ms: float = 5.0  # per network hop in the agg tree
    flink_spare_slots: bool = False  # spare TaskManager slots for failover

    @property
    def batch_span_ms(self) -> float:
        return 1000.0 * self.events_per_batch / self.rate_per_partition

    @property
    def horizon_ms(self) -> float:
        return self.num_batches * self.batch_span_ms

    @property
    def initial_membership(self) -> tuple[int, ...]:
        """Node ids present at t=0.  Scenarios reference membership through
        this (not raw ``range(num_nodes)``) so scale events stay valid."""
        return tuple(range(self.num_nodes))


EVENT_KINDS = (
    "crash", "restart", "scale_out", "scale_in",
    # network-fabric events (runtime/net.py, docs/protocol.md §4)
    "partition", "heal", "degrade",
)


@dataclasses.dataclass(frozen=True)
class ScenarioEvent:
    """One timed control-plane action over a set of node ids.

    ``partition`` carries ``groups`` (node-id sets that stay mutually
    connected) instead of ``nodes``; ``degrade`` carries the affected
    ``nodes`` plus the ``loss``/``jitter_ms`` overrides to apply (both None
    clears the nodes' degradation)."""

    t_ms: float
    kind: str  # one of EVENT_KINDS
    nodes: tuple[int, ...]
    groups: tuple[tuple[int, ...], ...] = ()
    loss: float | None = None
    jitter_ms: float | None = None

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown scenario event kind {self.kind!r}")
        if self.kind == "partition" and len(self.groups) < 2:
            raise ValueError("partition needs at least two groups")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """General timed control-plane script: crashes, restarts, elastic
    membership changes (docs/protocol.md §3), and network-fabric faults
    (docs/protocol.md §4).  Build fluently:

        Scenario("elastic").scale_out(4000, 4, 5).scale_in(9000, 4, 5)
        Scenario("split").partition(8000, (0, 1), (2, 3, 4)).heal(16000)

    ``crash``/``restart`` model unplanned failure + recovery of an existing
    node; ``scale_out`` adds brand-new nodes (or revives drained ones) that
    bootstrap from a live peer; ``scale_in`` drains nodes gracefully — final
    delta flush + handoff checkpoints before departure.  ``partition``
    splits the network into mutually unreachable groups until ``heal``;
    ``degrade`` worsens (or, with no overrides, restores) the links touching
    a set of nodes.
    """

    name: str = "baseline"
    events: tuple[ScenarioEvent, ...] = ()

    def _add(self, ev: ScenarioEvent) -> "Scenario":
        return dataclasses.replace(self, events=self.events + (ev,))

    def at(self, t_ms: float, kind: str, *nodes: int) -> "Scenario":
        return self._add(ScenarioEvent(float(t_ms), kind, tuple(int(n) for n in nodes)))

    def crash(self, t_ms: float, *nodes: int) -> "Scenario":
        return self.at(t_ms, "crash", *nodes)

    def restart(self, t_ms: float, *nodes: int) -> "Scenario":
        return self.at(t_ms, "restart", *nodes)

    def scale_out(self, t_ms: float, *nodes: int) -> "Scenario":
        return self.at(t_ms, "scale_out", *nodes)

    def scale_in(self, t_ms: float, *nodes: int) -> "Scenario":
        return self.at(t_ms, "scale_in", *nodes)

    def partition(self, t_ms: float, *groups) -> "Scenario":
        """Split the fabric into ``groups`` (iterables of node ids) that can
        only talk within themselves; nodes in no group form one residual
        side, and checkpoint storage stays reachable from everyone."""
        gs = tuple(tuple(int(n) for n in g) for g in groups)
        return self._add(ScenarioEvent(float(t_ms), "partition", (), groups=gs))

    def heal(self, t_ms: float) -> "Scenario":
        return self._add(ScenarioEvent(float(t_ms), "heal", ()))

    def degrade(
        self, t_ms: float, nodes, loss: float | None = None,
        jitter_ms: float | None = None,
    ) -> "Scenario":
        """Worsen every link touching ``nodes`` (loss and/or uniform jitter
        on top of the configured profile); with both overrides None the
        nodes' degradation is cleared."""
        ns = tuple(int(n) for n in nodes)
        return self._add(
            ScenarioEvent(float(t_ms), "degrade", ns, loss=loss, jitter_ms=jitter_ms)
        )

    @classmethod
    def baseline(cls) -> "Scenario":
        return cls()


@dataclasses.dataclass(frozen=True)
class FailureScenario:
    """When nodes fail and (optionally) restart, in simulated ms.

    The crash/restart-only ancestor of :class:`Scenario`, kept as the
    ergonomic spelling of the paper's three scenarios (§5.2):
      concurrent: two nodes at t, restart t+10s
      subsequent: two nodes at t, t+5s; each restarts 10s after its failure
      crash:      two nodes at t, never restarted
    """

    name: str = "baseline"
    fail_times_ms: tuple[float, ...] = ()
    fail_nodes: tuple[int, ...] = ()
    restart_times_ms: tuple[float, ...] = ()  # -1 = never

    def to_scenario(self) -> Scenario:
        s = Scenario(name=self.name)
        for t, nid, rt in zip(self.fail_times_ms, self.fail_nodes, self.restart_times_ms):
            s = s.crash(t, nid)
            if rt >= 0:
                s = s.restart(rt, nid)
        return s

    @classmethod
    def baseline(cls):
        return cls()

    @classmethod
    def concurrent(cls, t: float = 8000.0, nodes: tuple[int, int] = (0, 1)):
        return cls(
            name="concurrent",
            fail_times_ms=(t, t),
            fail_nodes=tuple(nodes),
            restart_times_ms=(t + 10_000, t + 10_000),
        )

    @classmethod
    def subsequent(cls, t: float = 8000.0, nodes: tuple[int, int] = (0, 1)):
        return cls(
            name="subsequent",
            fail_times_ms=(t, t + 5_000),
            fail_nodes=tuple(nodes),
            restart_times_ms=(t + 10_000, t + 15_000),
        )

    @classmethod
    def crash(cls, t: float = 8000.0, nodes: tuple[int, int] = (0, 1)):
        return cls(
            name="crash",
            fail_times_ms=(t, t),
            fail_nodes=tuple(nodes),
            restart_times_ms=(-1.0, -1.0),
        )


def as_scenario(scenario: "Scenario | FailureScenario | None") -> Scenario:
    """Normalize any scenario spelling (or None) to the event-list form."""
    if scenario is None:
        return Scenario()
    if isinstance(scenario, FailureScenario):
        return scenario.to_scenario()
    return scenario
