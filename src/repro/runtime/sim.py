"""Minimal deterministic discrete-event simulator."""
from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable


class Sim:
    def __init__(self):
        self._heap: list[tuple[float, int, Callable[[], None]]] = []
        self._counter = itertools.count()  # FIFO tie-break => determinism
        self.now: float = 0.0

    def at(self, t: float, fn: Callable[[], None]) -> None:
        if t < self.now:
            t = self.now
        heapq.heappush(self._heap, (t, next(self._counter), fn))

    def after(self, dt: float, fn: Callable[[], None]) -> None:
        self.at(self.now + dt, fn)

    def run(self, until: float) -> None:
        while self._heap and self._heap[0][0] <= until:
            t, _, fn = heapq.heappop(self._heap)
            self.now = t
            fn()
        self.now = max(self.now, until)
