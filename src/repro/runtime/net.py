"""Simulated network fabric: lossy, partitionable, byte-metered transport.

Every inter-node and node↔storage message in both runtimes (the Holon
harness *and* the Flink-like baseline, so comparisons stay apples-to-apples)
is delivered by a :class:`NetworkFabric` instead of raw ``sim.after``
callbacks.  The fabric owns the properties a transport can have:

* **latency** — seeded deterministic per-link distributions: fixed, or
  fixed + uniform / lognormal jitter (``LinkProfile.jitter``);
* **loss** — per-message Bernoulli drop (``LinkProfile.loss``);
* **bounded reordering** — jitter alone reorders within its window; an
  explicit ``reorder_prob``/``reorder_ms`` adds occasional extra delay;
* **partitions** — ``set_partition(groups…)`` blocks every link between
  groups until ``heal()``; nodes absent from every group form one implicit
  residual side.  Storage is a separate service and stays reachable;
* **degradation** — ``degrade(nodes, …)`` worsens every link touching the
  named nodes (loss / jitter / latency), e.g. one slow rack;
* **byte metering** — per-message-class and per-link counters
  (:class:`ClassStats`), unifying what used to be ad-hoc ``delta_bytes``
  accounting in the harness.

Which guarantee each message class actually *needs* — and why CRDT gossip
tolerates the fire-and-forget tier while bootstrap/handoff ride the retried
tier — is specified in docs/protocol.md §4 (Transport semantics); the
design rationale is DESIGN.md §9.  In
short: gossip (``hb``/``sync``/``sync_ack``/``sync_nack``) is lossy
fire-and-forget, because idempotent lattice joins make any later delivery
subsume a lost one; storage RPCs (``ckpt_put``/``ckpt_get``) are retried
request-response over idempotent handlers; the joiner's ``state_req`` and
the centralized baseline's ``shuffle`` partials ride a reliable (TCP-like)
tier — loss becomes retransmit delay, partitions park the message until
heal (a bootstrap request must survive the partition it was born into).

Determinism: every random draw comes from a per-link ``random.Random``
seeded by ``mix64(seed, src, dst)``, so (a) the same config+seed replays a
byte-identical delivery ``trace``, and (b) traffic on one link never
perturbs another link's draws.  A lossless zero-jitter profile makes *no*
RNG draws at all and schedules exactly one simulator event per message at
``latency_ms`` — the pre-fabric wire, preserved bit-for-bit.

Delivery records are typed :class:`~repro.obs.records.TraceEvent`s
(``kind="net.msg"``) in the harness telemetry's bounded ring buffer
(docs/observability.md §2) — recorded when ``SimConfig.net_trace`` or
``obs`` is set, off by default so long chaos sweeps don't retain
per-message state, and bounded either way so they can't grow memory
without bound.  Recording is passive: it never draws RNG or schedules
events, so the lossless-profile bit-for-bit guarantee holds with tracing
on or off.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable, Hashable, Iterable

from repro.obs.telemetry import Telemetry

# the durable checkpoint service rides the fabric as a distinguished
# endpoint: always reachable (it is not a cluster member), with its own
# LinkProfile (storage_rtt_ms latency, storage_loss)
STORAGE = "storage"

# nominal wire sizes for messages whose payload the simulation does not
# materialize (real payload classes — sync deltas, checkpoints — are
# metered with their measured nbytes)
HB_BYTES = 64.0
CTRL_BYTES = 16.0
# one (nid, timestamp) liveness-digest entry piggybacked on heartbeats by
# sparse dissemination topologies (docs/protocol.md §5): 4-byte id + 8-byte
# time.  All-to-all beacons carry no digest and stay at HB_BYTES.
GOSSIP_ENTRY_BYTES = 12.0

_M64 = (1 << 64) - 1


def _mix64(*parts: int) -> int:
    """splitmix64-style combine — stable across processes (no PYTHONHASHSEED)."""
    x = 0x9E3779B97F4A7C15
    for p in parts:
        x = (x ^ (p & _M64)) * 0xBF58476D1CE4E5B9 & _M64
        x ^= x >> 27
        x = (x * 0x94D049BB133111EB) & _M64
        x ^= x >> 31
    return x


def _endpoint_id(e: Hashable) -> int:
    return -1 if e == STORAGE else int(e)


@dataclasses.dataclass(frozen=True)
class LinkProfile:
    """Delivery characteristics of one link direction (docs/protocol.md §4)."""

    latency_ms: float = 5.0  # base one-way latency
    jitter: str = "fixed"  # fixed | uniform | lognormal
    jitter_ms: float = 0.0  # uniform: +U(0, j); lognormal: median extra ≈ j
    loss: float = 0.0  # per-message drop probability
    reorder_prob: float = 0.0  # chance of an extra bounded-reorder delay
    reorder_ms: float = 0.0  # size of that extra delay window

    def __post_init__(self):
        if self.jitter not in ("fixed", "uniform", "lognormal"):
            raise ValueError(f"unknown jitter distribution {self.jitter!r}")

    @property
    def needs_rng(self) -> bool:
        return (
            self.loss > 0.0
            or (self.jitter != "fixed" and self.jitter_ms > 0.0)
            or self.reorder_prob > 0.0
        )


@dataclasses.dataclass
class ClassStats:
    """Wire accounting for one message class (bytes are metered at send
    time: a dropped packet still consumed sender bandwidth)."""

    msgs: int = 0
    bytes: float = 0.0
    dropped: int = 0  # lost + partitioned fire-and-forget messages
    retries: int = 0  # reliable-transport retransmits / RPC re-issues


class NetworkFabric:
    """All message delivery for one simulated deployment.

    ``send`` is the lossy fire-and-forget tier, ``send_reliable`` the
    TCP-like tier (loss → retransmit delay, partition → park until heal),
    ``rpc`` the retried request-response tier for idempotent storage and
    bootstrap handlers.  See docs/protocol.md §4 for which message class
    uses which tier and why that suffices for convergence.
    """

    @classmethod
    def from_config(cls, sim, cfg, telemetry: Telemetry | None = None) -> "NetworkFabric":
        """The one place SimConfig's net knobs become link profiles — both
        runtimes build their fabric here, so they cannot drift apart.
        ``telemetry`` shares the harness's trace buffer so net records and
        protocol spans land in one time-ordered stream."""
        return cls(
            sim,
            profile=LinkProfile(
                latency_ms=cfg.broadcast_delay_ms,
                jitter=cfg.net_jitter,
                jitter_ms=cfg.net_jitter_ms,
                loss=cfg.net_loss,
                reorder_prob=cfg.net_reorder_prob,
                reorder_ms=cfg.net_reorder_ms,
            ),
            storage_profile=LinkProfile(
                latency_ms=cfg.storage_rtt_ms, loss=cfg.storage_loss
            ),
            seed=cfg.seed if cfg.net_seed < 0 else cfg.net_seed,
            rto_ms=cfg.net_rto_ms,
            retry_ms=cfg.storage_retry_ms,
            record_trace=cfg.net_trace,
            telemetry=telemetry,
        )

    def __init__(
        self,
        sim,
        profile: LinkProfile | None = None,
        storage_profile: LinkProfile | None = None,
        seed: int = 0,
        rto_ms: float = 200.0,
        retry_ms: float = 100.0,
        record_trace: bool = False,
        telemetry: Telemetry | None = None,
    ):
        self.sim = sim
        self.profile = profile if profile is not None else LinkProfile()
        self.storage_profile = (
            storage_profile
            if storage_profile is not None
            else LinkProfile(latency_ms=50.0)
        )
        self.seed = int(seed)
        self.rto_ms = float(rto_ms)
        self.retry_ms = float(retry_ms)
        # shared harness telemetry, or a standalone one for bare fabrics;
        # record_trace=True enables its net-record stream either way
        self.telemetry = (
            telemetry
            if telemetry is not None
            else Telemetry(sim, trace_net=record_trace)
        )
        if record_trace:
            self.telemetry.trace_net = True
        self.groups: tuple[frozenset, ...] | None = None
        self._degraded: dict[Hashable, dict] = {}
        self._rngs: dict[tuple[int, int], random.Random] = {}
        self.stats: dict[str, ClassStats] = {}
        self.link_bytes: dict[tuple[Hashable, Hashable], float] = {}
        # parked reliable messages, re-sent on heal: (src, dst, cls, nbytes,
        # deliver, latency_ms, hops)
        self._parked: list[tuple] = []
        # per-class histogram cache: skips the registry's key-string build on
        # every delivery (the fabric is the hottest telemetry call site)
        self._delay_hists: dict[str, object] = {}

    @property
    def record_trace(self) -> bool:
        return self.telemetry.trace_net

    @property
    def trace(self) -> list:
        """Typed per-message delivery records (``TraceEvent``, kind
        ``net.msg``), oldest-first, from the bounded telemetry ring."""
        return self.telemetry.net_events()

    # ---- topology control --------------------------------------------------
    def set_partition(self, *groups: Iterable[Hashable]) -> None:
        """Split the cluster: only links within one group keep delivering.
        Nodes listed in no group form one implicit residual side; STORAGE
        stays reachable from everyone (it is a service, not a member)."""
        self.groups = tuple(frozenset(g) for g in groups)
        self.telemetry.event(
            "net.partition", groups=tuple(tuple(sorted(g)) for g in self.groups)
        )

    def heal(self) -> None:
        """Remove the partition and flush parked reliable messages (they
        deliver after a freshly sampled latency from heal time)."""
        self.telemetry.event("net.heal", parked=len(self._parked))
        self.groups = None
        parked, self._parked = self._parked, []
        for src, dst, cls, nbytes, deliver, latency_ms, hops in parked:
            self.send_reliable(
                src, dst, cls, nbytes, deliver, latency_ms=latency_ms, hops=hops
            )

    def partitioned(self) -> bool:
        return self.groups is not None

    def reachable(self, a: Hashable, b: Hashable) -> bool:
        if a == b or self.groups is None or STORAGE in (a, b):
            return True
        ga = gb = None
        for i, g in enumerate(self.groups):
            if a in g:
                ga = i
            if b in g:
                gb = i
        return ga == gb

    def degrade(
        self,
        nodes: Iterable[Hashable],
        loss: float | None = None,
        jitter_ms: float | None = None,
        latency_ms: float | None = None,
        jitter: str | None = None,
    ) -> None:
        """Worsen every link touching ``nodes``.  Numeric overrides combine
        with the base profile (and each other) by max — degradation never
        improves a link.  All-None clears the nodes' overrides."""
        fields = {
            k: v
            for k, v in (
                ("loss", loss),
                ("jitter_ms", jitter_ms),
                ("latency_ms", latency_ms),
                ("jitter", jitter),
            )
            if v is not None
        }
        # a jitter_ms override on a fixed-latency profile implies a
        # distribution; default to uniform so the knob has an effect
        if jitter_ms is not None and jitter is None and self.profile.jitter == "fixed":
            fields["jitter"] = "uniform"
        self.telemetry.event(
            "net.degrade", nodes=tuple(sorted(_endpoint_id(n) for n in nodes)),
            status="set" if fields else "clear",
        )
        for n in nodes:
            if fields:
                self._degraded[n] = {**self._degraded.get(n, {}), **fields}
            else:
                self._degraded.pop(n, None)

    # ---- link resolution ---------------------------------------------------
    def _profile(self, src: Hashable, dst: Hashable) -> LinkProfile:
        prof = self.storage_profile if STORAGE in (src, dst) else self.profile
        ov: dict = {}
        for e in (src, dst):
            for k, v in self._degraded.get(e, {}).items():
                if k == "jitter":
                    ov[k] = v
                else:
                    base = getattr(prof, k)
                    ov[k] = max(ov.get(k, base), base, v)
        return dataclasses.replace(prof, **ov) if ov else prof

    def _lat_floor(self, src: Hashable, dst: Hashable) -> float:
        """Degraded-link latency floor — applies even to messages that carry
        their own base latency (e.g. the baseline's shuffle hops), so
        ``degrade(latency_ms=…)`` slows every class on the link."""
        f = 0.0
        for e in (src, dst):
            v = self._degraded.get(e, {}).get("latency_ms")
            if v is not None:
                f = max(f, v)
        return f

    def _rng(self, src: Hashable, dst: Hashable) -> random.Random:
        key = (_endpoint_id(src), _endpoint_id(dst))
        rng = self._rngs.get(key)
        if rng is None:
            rng = self._rngs[key] = random.Random(_mix64(self.seed, *key))
        return rng

    def _sample_latency(
        self,
        prof: LinkProfile,
        rng: random.Random | None,
        latency_ms: float | None,
        floor: float = 0.0,
    ) -> float:
        d = prof.latency_ms if latency_ms is None else max(latency_ms, floor)
        if rng is None:
            return d
        if prof.jitter == "uniform" and prof.jitter_ms > 0.0:
            d += rng.uniform(0.0, prof.jitter_ms)
        elif prof.jitter == "lognormal" and prof.jitter_ms > 0.0:
            d += prof.jitter_ms * rng.lognormvariate(0.0, 0.6)
        if prof.reorder_prob > 0.0 and rng.random() < prof.reorder_prob:
            d += rng.uniform(0.0, prof.reorder_ms)
        return d

    # ---- metering ----------------------------------------------------------
    def _meter(self, src, dst, cls: str, nbytes: float) -> ClassStats:
        st = self.stats.get(cls)
        if st is None:
            st = self.stats[cls] = ClassStats()
        st.msgs += 1
        st.bytes += nbytes
        link = (src, dst)
        self.link_bytes[link] = self.link_bytes.get(link, 0.0) + nbytes
        return st

    def _record(self, src, dst, cls, nbytes, status, t_deliver=-1.0, retries=0):
        self.telemetry.net_msg(src, dst, cls, nbytes, status, t_deliver, retries)

    def _observe_delay(self, cls: str, delay: float) -> None:
        """Per-class delivery-latency histogram — the wire-time slice of the
        per-phase breakdown (e.g. ``net_delivery_ms{cls=sync}`` is the sync
        phase's transport cost, docs/observability.md §1)."""
        if self.telemetry.on:
            h = self._delay_hists.get(cls)
            if h is None:
                h = self._delay_hists[cls] = self.telemetry.registry.histogram(
                    "net_delivery_ms", cls=cls)
            h.observe(delay)

    def msgs_of(self, cls: str) -> int:
        return self.stats[cls].msgs if cls in self.stats else 0

    def bytes_of(self, cls: str) -> float:
        return self.stats[cls].bytes if cls in self.stats else 0.0

    def dropped_of(self, cls: str) -> int:
        return self.stats[cls].dropped if cls in self.stats else 0

    def total_bytes(self) -> float:
        return sum(s.bytes for s in self.stats.values())

    def class_stats(self) -> dict[str, dict]:
        return {cls: dataclasses.asdict(s) for cls, s in sorted(self.stats.items())}

    # ---- transport tiers ---------------------------------------------------
    def send(
        self,
        src: Hashable,
        dst: Hashable,
        cls: str,
        nbytes: float,
        deliver: Callable[[], None],
        latency_ms: float | None = None,
    ) -> bool:
        """Fire-and-forget (gossip tier): deliver once after the sampled
        link latency, or drop silently on loss / partition.  Returns whether
        the message was scheduled for delivery."""
        st = self._meter(src, dst, cls, nbytes)
        if not self.reachable(src, dst):
            st.dropped += 1
            self._record(src, dst, cls, nbytes, "partitioned")
            return False
        prof = self._profile(src, dst)
        rng = self._rng(src, dst) if prof.needs_rng else None
        if prof.loss > 0.0 and rng.random() < prof.loss:
            st.dropped += 1
            self._record(src, dst, cls, nbytes, "lost")
            return False
        delay = self._sample_latency(prof, rng, latency_ms, self._lat_floor(src, dst))
        self._record(src, dst, cls, nbytes, "ok", self.sim.now + delay)
        self._observe_delay(cls, delay)
        self.sim.after(delay, deliver)
        return True

    def send_reliable(
        self,
        src: Hashable,
        dst: Hashable,
        cls: str,
        nbytes: float,
        deliver: Callable[[], None],
        latency_ms: float | None = None,
        hops: int = 1,
    ) -> None:
        """Reliable (TCP-like) tier, used by the centralized baseline's
        shuffle partials and the joiner's ``state_req``: each lost
        transmission costs one ``rto_ms`` retransmit delay per hop; a
        partitioned link parks the message until ``heal()``."""
        if not self.reachable(src, dst):
            self._meter(src, dst, cls, nbytes)
            self._parked.append((src, dst, cls, nbytes, deliver, latency_ms, hops))
            self._record(src, dst, cls, nbytes, "parked")
            return
        prof = self._profile(src, dst)
        rng = self._rng(src, dst) if prof.needs_rng else None
        floor = self._lat_floor(src, dst)
        delay, retries = 0.0, 0
        for _ in range(max(1, hops)):
            if prof.loss > 0.0:
                while retries < 64 and rng.random() < prof.loss:
                    retries += 1
                    delay += self.rto_ms
            delay += self._sample_latency(prof, rng, latency_ms, floor)
        st = self._meter(src, dst, cls, nbytes * (1 + retries))
        st.retries += retries
        # retries ride the record so critical-path analysis can split the
        # delivery delay into wire time vs retransmit stalls (obs/critpath.py)
        self._record(src, dst, cls, nbytes, "ok", self.sim.now + delay,
                     retries=retries)
        self._observe_delay(cls, delay)
        self.sim.after(delay, deliver)

    def rpc(
        self,
        src: Hashable,
        dst: Hashable,
        cls: str,
        nbytes: float,
        execute: Callable[[], None],
        latency_ms: float | None = None,
        max_tries: int = 10,
    ) -> None:
        """At-least-once request-response collapsed to one modeled round
        trip: ``execute()`` runs at the RTT point; loss of either leg (or a
        partition) re-issues the whole exchange after ``retry_ms``.  Only
        for idempotent handlers — checkpoint merge-on-put, checkpoint get,
        both are (docs/protocol.md §4)."""

        def attempt(tries_left: int):
            st = self._meter(src, dst, cls, nbytes)
            prof = self._profile(src, dst)
            rng = self._rng(src, dst) if prof.needs_rng else None
            failed = not self.reachable(src, dst) or (
                prof.loss > 0.0 and rng.random() < prof.loss
            )
            if failed:
                st.dropped += 1
                if tries_left > 1:
                    st.retries += 1
                    self._record(src, dst, cls, nbytes, "retry")
                    self.sim.after(self.retry_ms, lambda: attempt(tries_left - 1))
                else:
                    self._record(src, dst, cls, nbytes, "gave_up")
                return
            delay = self._sample_latency(
                prof, rng, latency_ms, self._lat_floor(src, dst)
            )
            self._record(src, dst, cls, nbytes, "ok", self.sim.now + delay)
            self._observe_delay(cls, delay)
            self.sim.after(delay, execute)

        attempt(max_tries)
