"""Decentralized Holon runtime (paper §4) + the centralized Flink-like
baseline it is evaluated against, both driven by a discrete-event simulator.

The *state transitions* are the real JAX dataplane (WCRDT folds / lattice
joins / window reads); only *time* is modeled (network delay, heartbeats,
checkpoint RTT), with the cost constants documented in ``SimConfig`` and
EXPERIMENTS.md.  This is the honest CPU-container stand-in for the paper's
GCP/Kafka deployment: relative behaviour (recovery time, sensitivity,
scalability) is reproduced; absolute wall-clock numbers are simulation time.
"""
from repro.runtime.config import (
    SimConfig,
    FailureScenario,
    Scenario,
    ScenarioEvent,
    as_scenario,
)
from repro.runtime.consumer import Consumer
from repro.runtime.net import LinkProfile, NetworkFabric
from repro.runtime.storage import CheckpointStorage
from repro.runtime.harness import HolonHarness, assignment, run_holon
from repro.runtime.flink_baseline import FlinkHarness, run_flink
from repro.runtime.topology import (
    AllToAll,
    EpochRing,
    Hypercube,
    PartialView,
    Topology,
    topology_from_spec,
)

__all__ = [
    "SimConfig",
    "FailureScenario",
    "Scenario",
    "ScenarioEvent",
    "as_scenario",
    "assignment",
    "Consumer",
    "CheckpointStorage",
    "LinkProfile",
    "NetworkFabric",
    "HolonHarness",
    "run_holon",
    "FlinkHarness",
    "run_flink",
    "Topology",
    "AllToAll",
    "EpochRing",
    "Hypercube",
    "PartialView",
    "topology_from_spec",
]
