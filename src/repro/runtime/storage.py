"""Remote checkpoint storage (the paper's ``storage.put/get``).

Durable key→blob store with modeled RTT.  Values are host pytrees (device
arrays are fine — they are immutable).  Merge-on-put keeps the largest
``nxt_idx`` per Algorithm 2's lattice rule, so concurrent checkpointers of the
same partition (allowed by the paper) can never regress a checkpoint.
"""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass
class PartitionCheckpoint:
    nxt_idx: int  # next input-log index to read
    nxt_odx: int  # next output index
    emitted_upto: int  # first window id not yet emitted
    shared: Any  # tuple[WState, ...] replica snapshot
    local: Any  # WLocal state (or None)


class CheckpointStorage:
    def __init__(self):
        self._data: dict[int, PartitionCheckpoint] = {}
        self.puts = 0
        self.gets = 0

    def put(self, pid: int, ckpt: PartitionCheckpoint) -> None:
        self.puts += 1
        cur = self._data.get(pid)
        # Algorithm 2: lattice merge keeps the state with the largest nxtIdx.
        if cur is None or ckpt.nxt_idx >= cur.nxt_idx:
            self._data[pid] = ckpt

    def get(self, pid: int) -> PartitionCheckpoint | None:
        self.gets += 1
        return self._data.get(pid)

    def has(self, pid: int) -> bool:
        return pid in self._data
