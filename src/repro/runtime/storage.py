"""Remote checkpoint storage (the paper's ``storage.put/get``).

Durable key→blob store.  Values are host pytrees (device arrays are fine —
they are immutable).  Merge-on-put keeps the largest ``nxt_idx`` per
Algorithm 2's lattice rule, so concurrent checkpointers of the same
partition (allowed by the paper) can never regress a checkpoint
(join-semilattice laws property-tested in tests/test_storage.py).

All access rides the network fabric's retried request-response tier
(docs/protocol.md §4): the service itself is synchronous and durable;
latency, loss, and retries live on the node↔storage links, and the lattice
rule is exactly what makes re-issued puts harmless.

With telemetry attached (docs/observability.md §2) the store records one
``ckpt.apply`` per put — carrying the *resulting* stored frontier, which is
what the auditor's monotone-frontier invariant checks: put *requests* may
arrive out of order, the applied frontier may never regress — and one
``ckpt.get`` per fetch (hit/miss + the recovered frontier).
"""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass
class PartitionCheckpoint:
    nxt_idx: int  # next input-log index to read
    nxt_odx: int  # next output index
    emitted_upto: int  # first window id not yet emitted
    shared: Any  # tuple[WState, ...] replica snapshot
    local: Any  # WLocal state (or None)
    # delta-sync coverage marker: per shared spec the (folded, progress) the
    # snapshot covers.  Recovery restarts delta replay from exactly here —
    # a peer whose delta baseline exceeds it gets nacked into a full resync.
    # Host-side numpy (derivable from ``shared``, but kept materialized so
    # storage can compare coverage without touching device arrays).
    baseline: Any = None
    # membership epoch the checkpointing node had gossiped when it took the
    # snapshot (docs/protocol.md §3.3): recovery can tell a pre- from a
    # post-reconfiguration checkpoint, and put() prefers the newer view on
    # otherwise-equal snapshots.
    epoch: int = 0


def _progress_of(ckpt: PartitionCheckpoint) -> tuple:
    """The snapshot's per-partition watermark vector (first shared spec's
    progress — the one ``global_watermark`` reads); () when stateless."""
    if ckpt.baseline is None or not len(ckpt.baseline):
        return ()
    return tuple(int(x) for x in ckpt.baseline[0][1])


def _coverage(ckpt: PartitionCheckpoint) -> float:
    """Total gossip coverage of a checkpoint (sum of folded frontiers)."""
    if ckpt.baseline is None:
        return 0.0
    return float(sum(folded.sum() for folded, _ in ckpt.baseline))


class CheckpointStorage:
    def __init__(self, telemetry=None):
        self._data: dict[int, PartitionCheckpoint] = {}
        self.puts = 0
        self.gets = 0
        self.obs = telemetry  # Telemetry or None (docs/observability.md §2)

    def put(self, pid: int, ckpt: PartitionCheckpoint) -> None:
        self.puts += 1
        cur = self._data.get(pid)
        # Algorithm 2: lattice merge keeps the state with the largest nxtIdx;
        # ties broken by delta-sync coverage (richer gossip wins, so recovery
        # replays the fewest deltas), then by membership epoch (newer view).
        applied = cur is None or (
            (ckpt.nxt_idx, _coverage(ckpt), ckpt.epoch)
            >= (cur.nxt_idx, _coverage(cur), cur.epoch)
        )
        if applied:
            self._data[pid] = ckpt
        if self.obs is not None and self.obs.on:
            stored = self._data[pid]
            self.obs.event(
                "ckpt.apply", node="storage", partition=pid,
                status="applied" if applied else "kept",
                nxt_idx=stored.nxt_idx, epoch=stored.epoch,
                # stored snapshot's progress vector (first shared spec):
                # critical-path analysis restores adopted lanes from exactly
                # what a later ckpt.get hands out (obs/critpath.py)
                wm=_progress_of(stored),
            )
            self.obs.registry.counter("ckpt_puts", partition=pid).inc()

    def get(self, pid: int) -> PartitionCheckpoint | None:
        self.gets += 1
        ck = self._data.get(pid)
        if self.obs is not None and self.obs.on:
            self.obs.event(
                "ckpt.get", node="storage", partition=pid,
                status="hit" if ck is not None else "miss",
                nxt_idx=ck.nxt_idx if ck is not None else -1,
            )
            self.obs.registry.counter("ckpt_gets", partition=pid).inc()
        return ck

    def has(self, pid: int) -> bool:
        return pid in self._data
