"""Windowed CRDTs — Algorithm 1 of the paper, vectorized for JAX.

A WCRDT wraps any CRDT from ``crdt.py`` with:

* a ring of ``W`` window slots (every CRDT leaf gains a leading ``[W]`` axis),
* ``slot_wid[W]`` recording which window id each slot currently holds,
* a ``progress[P]`` map of per-partition local watermarks (event timestamps),
* monotone error counters (late drops, incomplete evictions, ring overflows).

Semantics (paper §4.2):
  - ``insert`` folds a *batch* of timestamped events into their window slots
    (one vectorized scatter instead of the paper's per-event loop — the TPU
    adaptation of the hot path; see kernels/window_agg).
  - ``increment_watermark`` raises this partition's progress entry.
  - ``global_watermark`` = min over all progress entries.
  - ``window_value(wid)`` is readable iff the global watermark has passed the
    window's end — at that point the value is final and identical on every
    replica (*global determinism*).
  - ``merge`` is a join: slots ordered lexicographically by (wid, CRDT join),
    progress joined by elementwise max.  Commutative / associative /
    idempotent, hence convergent under any gossip or collective schedule.

Deviation from the paper (recorded in DESIGN.md §3): the paper keys progress
by *node*; we key it by *partition*.  With work stealing a node may die and
its partitions move — a node-keyed map would freeze the global watermark on
the dead node's stale entry, while the partition-keyed map travels with the
stolen partition state.  The paper's evaluation (fixed partition count) is
unaffected.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import crdt as crdts
from repro.core.lattice import Reduce, join, join_stacked, lattice_dataclass
from repro.core.window import Hopping, Tumbling, WindowAssigner, expand_events

NO_WID = jnp.int32(-1)
ERR_LATE = 0  # events older than the partition's own watermark (paper: error)
ERR_RING = 1  # events whose window had already been evicted from the ring
ERR_EVICT_INCOMPLETE = 2  # slot reused before its window completed (W too small)
NUM_ERRS = 3


@lattice_dataclass(
    slot_wid="custom", windows="custom", progress="custom", folded="custom",
    errors="custom",
)
class WState:
    """Replica state of one Windowed CRDT.

    ``folded`` is the per-partition *batch frontier*: the number of input-log
    batches already folded for that partition, merged by max.  It makes
    ``insert`` idempotent under deterministic replay — a recovering node that
    replays batches its pre-crash gossip already delivered folds nothing
    (Algorithm 2's "largest nxtIdx wins" applied inside the WCRDT; this
    closed a measured exactly-once violation where the boundary event with
    ts == progress[p] was re-folded into the merged slot)."""

    slot_wid: jax.Array  # i32[W], window id held by each ring slot (-1 empty)
    windows: Any  # CRDT pytree, leaves [W, ...]
    progress: jax.Array  # i32[P], per-partition local watermark (timestamps)
    folded: jax.Array  # i32[P], per-partition batch frontier
    errors: jax.Array  # i32[NUM_ERRS], monotone counters

    def merge(self, other: "WState") -> "WState":
        return _merge_wstate(self, other)


def _merge_wstate(a: WState, b: WState) -> WState:
    """Slot-aware lattice join.

    Per slot: larger wid wins outright (the smaller is a stale ring tenant);
    equal wids join the underlying CRDT.  This is the product of the
    lexicographic-by-wid order with the CRDT lattice — still a semilattice.
    """
    a_newer = a.slot_wid > b.slot_wid
    same = a.slot_wid == b.slot_wid
    joined = join(a.windows, b.windows)

    def pick(la, lb, lj):
        # broadcast slot masks over trailing dims
        extra = (1,) * (la.ndim - 1)
        newer = a_newer.reshape((-1, *extra))
        eq = same.reshape((-1, *extra))
        return jnp.where(eq, lj, jnp.where(newer, la, lb))

    windows = jax.tree.map(pick, a.windows, b.windows, joined)
    return WState(
        slot_wid=jnp.maximum(a.slot_wid, b.slot_wid),
        windows=windows,
        progress=jnp.maximum(a.progress, b.progress),
        folded=jnp.maximum(a.folded, b.folded),
        errors=jnp.maximum(a.errors, b.errors),
    )


@dataclasses.dataclass(frozen=True)
class WSpec:
    """Static spec of a Windowed CRDT (hashable; safe as a jit static arg)."""

    window_len: int  # window length in timestamp units
    num_slots: int  # ring size W (must exceed max watermark lag, in windows)
    num_partitions: int  # P — progress map size
    zero_windows: Callable[[], Any]  # () -> CRDT pytree with [W] leading axis
    fold: Callable[..., Any]  # (windows, slot_ids, mask, **inputs) -> windows
    read: Callable[[Any, jax.Array], Any]  # (windows, slot) -> value
    # Fast-fold hint: partition-ordered batches span few windows; when set,
    # insert() computes the batch's lowest window id and the fold only visits
    # this many window offsets (events beyond are dropped + counted ERR_RING).
    max_active_windows: int | None = None
    # Window shape (DESIGN.md §8): Tumbling reproduces the paper's
    # ``ts // window_len`` bit-for-bit; Hopping(window_len, hop) maps each
    # event into window_len // hop overlapping windows.  None -> Tumbling.
    assigner: WindowAssigner | None = None

    def __post_init__(self):
        if self.assigner is None:
            object.__setattr__(self, "assigner", Tumbling(self.window_len))
        elif self.assigner.window_len != self.window_len:
            raise ValueError(
                f"assigner window_len {self.assigner.window_len} != spec "
                f"window_len {self.window_len}"
            )
        if self.assigner.windows_per_event > self.num_slots:
            # one event's K concurrent windows can never all be resident:
            # every fold would evict incomplete windows and reads would
            # return ok=False with no hint why — reject up front
            raise ValueError(
                f"assigner spans {self.assigner.windows_per_event} concurrent "
                f"windows per event but the ring has only {self.num_slots} "
                "slots; raise num_slots or the hop"
            )

    def window_of(self, ts: jax.Array) -> jax.Array:
        """Newest window containing ``ts`` (the only one, under Tumbling)."""
        return self.assigner.window_of(ts)

    def zero(self) -> WState:
        return WState(
            slot_wid=jnp.full((self.num_slots,), NO_WID, dtype=jnp.int32),
            windows=self.zero_windows(),
            progress=jnp.zeros((self.num_partitions,), dtype=jnp.int32),
            folded=jnp.zeros((self.num_partitions,), dtype=jnp.int32),
            errors=jnp.zeros((NUM_ERRS,), dtype=jnp.int32),
        )


# ---------------------------------------------------------------------------
# Operations (pure; all jit / vmap friendly; spec is static)
# ---------------------------------------------------------------------------


def _expand_payload(x, B: int, K: int):
    """Repeat an event-aligned ``[B, ...]`` payload into ``[B*K, ...]`` lanes;
    scalars (e.g. ``actor=partition``) pass through untouched."""
    if getattr(x, "ndim", 0) >= 1 and x.shape[0] == B:
        return jnp.repeat(jnp.asarray(x), K, axis=0)
    return x


def insert(
    spec: WSpec, state: WState, partition, ts: jax.Array, mask: jax.Array,
    batch_idx=None, **inputs
) -> WState:
    """Fold a batch of events (timestamps ``ts``, payload ``inputs``) into the
    window ring for ``partition``.

    Batched Algorithm-1 INSERT: events below the partition's own watermark are
    dropped and counted (the paper raises an error); ring-slot reuse resets the
    slot's CRDT to zero first; events for already-evicted windows are dropped
    and counted.

    Under an overlapping assigner (DESIGN.md §8) each event multi-emits into
    its ``windows_per_event`` windows: the batch expands into ``[B*K]`` lanes
    (window ids + repeated payloads) and the same vectorized scatter folds
    them all — ERR_LATE stays per *event*, ERR_RING counts dropped
    (event, window) assignments.  Tumbling keeps the single-lane graph.

    ``batch_idx`` (optional): this batch's index in the partition's input log.
    When given, the fold is a no-op unless ``batch_idx >= folded[partition]``
    — replay-idempotence for exactly-once recovery (see WState.folded).
    """
    W = spec.num_slots
    ts = ts.astype(jnp.int32)
    if batch_idx is not None:
        fresh = jnp.asarray(batch_idx, jnp.int32) >= state.folded[partition]
        mask = mask & fresh

    # Algorithm 1 line 5: ts < progress[self] is an error -> count as late.
    # Per-event (before multi-window expansion) so each event counts once.
    late = mask & (ts < state.progress[partition])
    mask = mask & ~late
    n_late = jnp.sum(late).astype(jnp.int32)

    K = spec.assigner.windows_per_event
    if K == 1:
        wid = spec.assigner.window_of(ts)
    else:
        B = ts.shape[0]
        wid, mask = expand_events(spec.assigner, ts, mask)
        inputs = {k: _expand_payload(v, B, K) for k, v in inputs.items()}
    slot = wid % W

    # Newest incoming window id per slot (masked lanes contribute NO_WID).
    inc_wid = jnp.where(mask, wid, NO_WID)
    seg_max = jax.ops.segment_max(
        inc_wid, slot, num_segments=W, indices_are_sorted=False
    )
    seg_max = jnp.maximum(seg_max, NO_WID)  # empty segments -> -inf -> clamp
    new_slot_wid = jnp.maximum(state.slot_wid, seg_max)

    # Reset slots whose tenant window advances.
    advancing = new_slot_wid > state.slot_wid
    # eviction-safety diagnostic: old tenant not yet complete?
    gwm_wid = spec.assigner.first_dirty_wid(global_watermark(spec, state))
    evict_bad = advancing & (state.slot_wid >= 0) & (state.slot_wid >= gwm_wid)
    zeros = spec.zero_windows()

    def reset(leaf, zleaf):
        extra = (1,) * (leaf.ndim - 1)
        adv = advancing.reshape((-1, *extra))
        return jnp.where(adv, zleaf, leaf)

    windows = jax.tree.map(reset, state.windows, zeros)

    # Valid events: belong to the (new) tenant window of their slot.
    stale = mask & (wid < new_slot_wid[slot])
    valid = mask & ~stale
    n_ring = jnp.sum(stale).astype(jnp.int32)

    if spec.max_active_windows is not None:
        span = spec.max_active_windows
        lo = jnp.min(jnp.where(valid, wid, jnp.int32(2**31 - 1)))
        over = valid & (wid >= lo + span)
        valid = valid & ~over
        n_ring = n_ring + jnp.sum(over).astype(jnp.int32)
        windows = spec.fold(windows, slot, valid, lo=lo, **inputs)
    else:
        windows = spec.fold(windows, slot, valid, **inputs)

    errors = state.errors
    errors = errors.at[ERR_LATE].add(n_late)
    errors = errors.at[ERR_RING].add(n_ring)
    errors = errors.at[ERR_EVICT_INCOMPLETE].add(jnp.sum(evict_bad).astype(jnp.int32))

    folded = state.folded
    if batch_idx is not None:
        folded = folded.at[partition].max(jnp.asarray(batch_idx, jnp.int32) + 1)
    return WState(
        slot_wid=new_slot_wid, windows=windows, progress=state.progress,
        folded=folded, errors=errors,
    )


def increment_watermark(spec: WSpec, state: WState, partition, ts) -> WState:
    ts = jnp.asarray(ts, jnp.int32)
    new = state.progress.at[partition].max(ts)
    return dataclasses.replace(state, progress=new)


def global_watermark(spec: WSpec, state: WState) -> jax.Array:
    return jnp.min(state.progress)


def window_complete(spec: WSpec, state: WState, wid) -> jax.Array:
    """A window is complete once the global watermark passes its end (the
    assigner-provided extent — ``(wid+1)*window_len`` under Tumbling)."""
    wid = jnp.asarray(wid, jnp.int32)
    return global_watermark(spec, state) >= spec.assigner.end_ts(wid)


def window_value(spec: WSpec, state: WState, wid):
    """Unsafe-mode read: (value, ok).  ok=False means not complete (None in
    the paper) or already evicted from the ring.

    A complete window whose ring slot holds an OLDER tenant (or nothing) is
    globally EMPTY — inserts happen-before watermark bumps within one replica
    and merges carry both atomically, so completeness implies every
    partition's events for this window are visible.  Empty windows therefore
    read as the CRDT's zero aggregate, ok=True.
    """
    wid = jnp.asarray(wid, jnp.int32)
    slot = wid % spec.num_slots
    tenant = state.slot_wid[slot]
    resident = tenant == wid
    evicted = tenant > wid
    ok = window_complete(spec, state, wid) & ~evicted
    val = spec.read(state.windows, slot)
    zero_val = spec.read(spec.zero_windows(), slot)
    val = jax.tree.map(
        lambda v, z: jnp.where(resident, v, z), val, zero_val
    )
    return val, ok


def merge(spec: WSpec, a: WState, b: WState) -> WState:
    return _merge_wstate(a, b)


def axis_join(spec: WSpec, state: WState, axis_name: str) -> WState:
    """Background sync as a single collective across ``axis_name``.

    Generic path: all_gather + log-depth vectorized join (handles replicas at
    different ring positions).  The production metrics path uses
    ``axis_join_aligned`` which assumes lockstep slot_wid and rides pure
    pmax/pmin all-reduces (cheaper: no gather buffer).
    """
    gathered = jax.tree.map(lambda x: lax.all_gather(x, axis_name), state)
    return join_stacked(gathered, merge_fn=_merge_wstate)


def axis_join_aligned(spec: WSpec, state: WState, axis_name: str) -> WState:
    """Collective join assuming all replicas hold identical slot_wid (lockstep
    windows — true for the step-windowed training-metrics lattice).  Each leaf
    joins with its elementwise reduce: one fused all-reduce, no gather."""
    from repro.core.lattice import axis_reduce_leaf, field_kinds

    kinds = field_kinds(state.windows)
    joined = {}
    for name, kind in kinds.items():
        leaf = getattr(state.windows, name)
        if isinstance(kind, Reduce):
            joined[name] = jax.tree.map(
                lambda x, k=kind: axis_reduce_leaf(k, x, axis_name), leaf
            )
        else:
            # custom-merge sub-lattice (e.g. TopK): gather + fold
            g = jax.tree.map(lambda x: lax.all_gather(x, axis_name), leaf)
            n = jax.tree.leaves(g)[0].shape[0]
            parts = [jax.tree.map(lambda x: x[i], g) for i in range(n)]
            rebuilt = [
                dataclasses.replace(state.windows, **{name: p}) for p in parts
            ]
            from repro.core.lattice import join_many

            joined[name] = getattr(join_many(rebuilt), name)
    windows = dataclasses.replace(state.windows, **joined)
    return WState(
        slot_wid=lax.pmax(state.slot_wid, axis_name),
        windows=windows,
        progress=lax.pmax(state.progress, axis_name),
        folded=lax.pmax(state.folded, axis_name),
        errors=lax.pmax(state.errors, axis_name),
    )


# ---------------------------------------------------------------------------
# Delta-based synchronization (paper §7 future work, implemented)
# ---------------------------------------------------------------------------


def delta_since(
    spec: WSpec, state: WState, baseline_folded: jax.Array,
    baseline_progress: jax.Array,
) -> WState:
    """Extract an incremental sync delta: only ring slots that may have
    changed since the receiver's known ``(folded, progress)`` baseline.

    The delta IS a valid (partial) WState — untouched slots carry
    slot_wid = -1 and zero contents, which are the identities of the
    slot-aware join — so ``merge(remote, delta)`` applies exactly the dirty
    windows.  Determinism/convergence are unchanged (the delta is a point
    below ``state`` in the lattice); only sync bandwidth drops: for a
    window_len ≫ batch_span stream, one or two dirty slots per period instead
    of the whole ring (measured in tests/test_delta_sync.py).

    Dirty rule: events folded after the baseline have ts >= that partition's
    BASELINE watermark (older ones are late-dropped), so a slot is dirty iff
    its tenant window contains/exceeds the oldest baseline watermark among
    partitions whose batch frontier advanced — i.e. its tenant wid reaches
    ``assigner.first_dirty_wid(frontier)``, the smallest window any post-
    baseline event can land in (docs/protocol.md §2; under Tumbling this is
    the original ``frontier // window_len``).  Conservative and exact for
    in-order streams, overlapping windows included.
    """
    advanced = state.folded > baseline_folded
    any_adv = jnp.any(advanced)
    frontier_ts = jnp.min(
        jnp.where(advanced, baseline_progress, jnp.int32(2**31 - 1))
    )
    dirty_wid = spec.assigner.first_dirty_wid(jnp.maximum(frontier_ts, 0))
    dirty = (state.slot_wid >= dirty_wid) & any_adv

    zeros = spec.zero_windows()

    def pick(leaf, z):
        extra = (1,) * (leaf.ndim - 1)
        d = dirty.reshape((-1, *extra))
        return jnp.where(d, leaf, z)

    return WState(
        slot_wid=jnp.where(dirty, state.slot_wid, NO_WID),
        windows=jax.tree.map(pick, state.windows, zeros),
        progress=state.progress,  # tiny; always shipped
        folded=state.folded,
        errors=state.errors,
    )


def delta_nbytes(delta: WState) -> jax.Array:
    """Wire-size estimate of a delta: bytes of dirty slots + metadata.
    (The simulator charges this instead of the full-state size.)"""
    dirty = (delta.slot_wid >= 0).astype(jnp.float32)
    per_slot = sum(
        float(np.prod(l.shape[1:])) * l.dtype.itemsize
        for l in jax.tree.leaves(delta.windows)
    )
    meta = delta.progress.nbytes + delta.folded.nbytes + delta.errors.nbytes
    return jnp.sum(dirty) * per_slot + meta


def state_nbytes(state: WState) -> float:
    """Full-replica wire size (every leaf shipped) — the delta's comparand."""
    return float(sum(l.nbytes for l in jax.tree.leaves(state)))


def baseline_of(state: WState) -> tuple[jax.Array, jax.Array]:
    """The (folded, progress) marker summarizing what ``state`` covers — the
    receiver-side baseline that ``delta_since`` diffs against."""
    return (state.folded, state.progress)


def zero_baseline(spec: WSpec) -> tuple[np.ndarray, np.ndarray]:
    """Baseline of a peer known to hold nothing: the next delta is the full
    resident state."""
    z = np.zeros((spec.num_partitions,), dtype=np.int32)
    return (z, z.copy())


def merge_delta_stack(
    spec: WSpec, stacked: WState, use_pallas: bool | None = None,
    interpret: bool = False,
) -> WState:
    """Join an ``[R]``-stacked pile of deltas (from all_gather) slot-aware.

    Elementwise window lattices ride the gated delta-merge kernel: per ring
    slot, replicas whose tenant window trails the newest (including clean
    slots, ``slot_wid == -1``) are skipped instead of joined.  Custom window
    lattices (TopK) fall back to the log-depth vectorized pairwise join.
    """
    from repro.core.lattice import field_kinds

    kinds = field_kinds(stacked.windows)
    if not all(isinstance(k, Reduce) for k in kinds.values()):
        return join_stacked(stacked, merge_fn=_merge_wstate)

    from repro.kernels.ops import gated_delta_merge

    wid_stack = stacked.slot_wid  # [R, W]
    merged = {
        name: jax.tree.map(
            lambda x, k=kind: gated_delta_merge(
                wid_stack, x, op=k.value, use_pallas=use_pallas,
                interpret=interpret,
            ),
            getattr(stacked.windows, name),
        )
        for name, kind in kinds.items()
    }
    return WState(
        slot_wid=jnp.max(wid_stack, axis=0),
        windows=type(stacked.windows)(**merged),
        progress=jnp.max(stacked.progress, axis=0),
        folded=jnp.max(stacked.folded, axis=0),
        errors=jnp.max(stacked.errors, axis=0),
    )


def delta_axis_join(
    spec: WSpec, state: WState, baseline_folded: jax.Array,
    baseline_progress: jax.Array, axis_name: str,
    use_pallas: bool | None = None, interpret: bool = False,
) -> tuple[WState, jax.Array]:
    """Dirty-slot-gated background sync across ``axis_name``.

    Each replica extracts ``delta_since`` the shared post-last-sync baseline
    (after a sync round every replica holds the identical merged state, so
    its delta is exactly its own new contributions), the deltas are
    all-gathered, and the stack is joined by the gated delta-merge — clean
    slots are skipped rather than joined.  Returns ``(merged_state,
    shipped_nbytes)`` where the second is this replica's modeled wire cost
    (what a real transport would put on the network instead of the full
    ring; measured by benchmarks/throughput.py).
    """
    delta = delta_since(spec, state, baseline_folded, baseline_progress)
    shipped = delta_nbytes(delta)
    gathered = jax.tree.map(lambda x: lax.all_gather(x, axis_name), delta)
    merged = merge_delta_stack(
        spec, gathered, use_pallas=use_pallas, interpret=interpret
    )
    return _merge_wstate(state, merged), shipped



# ---------------------------------------------------------------------------
# Spec constructors for the CRDT catalog
# ---------------------------------------------------------------------------


def wgcounter(
    window_len: int, num_slots: int, num_partitions: int, key_shape=(), dtype=jnp.float32,
    assigner: WindowAssigner | None = None,
) -> WSpec:
    return WSpec(
        window_len=window_len,
        assigner=assigner,
        num_slots=num_slots,
        num_partitions=num_partitions,
        zero_windows=partial(
            crdts.GCounter.zero_windows, num_slots, num_partitions, key_shape, dtype
        ),
        fold=lambda w, s, m, actor, amounts, keys=None: w.fold_windows(
            s, m, actor, amounts, keys
        ),
        read=lambda w, slot: w.window_value(slot),
    )


def wpncounter(
    window_len: int, num_slots: int, num_partitions: int, key_shape=(), dtype=jnp.float32,
    assigner: WindowAssigner | None = None,
) -> WSpec:
    return WSpec(
        window_len=window_len,
        assigner=assigner,
        num_slots=num_slots,
        num_partitions=num_partitions,
        zero_windows=partial(
            crdts.PNCounter.zero_windows, num_slots, num_partitions, key_shape, dtype
        ),
        fold=lambda w, s, m, actor, amounts, keys=None: w.fold_windows(
            s, m, actor, amounts, keys
        ),
        read=lambda w, slot: w.window_value(slot),
    )


def wmaxreg(
    window_len: int, num_slots: int, num_partitions: int, key_shape=(), dtype=jnp.float32,
    assigner: WindowAssigner | None = None,
) -> WSpec:
    return WSpec(
        window_len=window_len,
        assigner=assigner,
        num_slots=num_slots,
        num_partitions=num_partitions,
        zero_windows=partial(crdts.MaxReg.zero_windows, num_slots, key_shape, dtype),
        fold=lambda w, s, m, vals, keys=None: w.fold_windows(s, m, vals, keys),
        read=lambda w, slot: w.window_value(slot),
    )


def wminreg(
    window_len: int, num_slots: int, num_partitions: int, key_shape=(), dtype=jnp.float32,
    assigner: WindowAssigner | None = None,
) -> WSpec:
    return WSpec(
        window_len=window_len,
        assigner=assigner,
        num_slots=num_slots,
        num_partitions=num_partitions,
        zero_windows=partial(crdts.MinReg.zero_windows, num_slots, key_shape, dtype),
        fold=lambda w, s, m, vals, keys=None: w.fold_windows(s, m, vals, keys),
        read=lambda w, slot: w.window_value(slot),
    )


def wtopk(
    window_len: int, num_slots: int, num_partitions: int, k: int,
    max_active_windows: int | None = 8,
    assigner: WindowAssigner | None = None,
) -> WSpec:
    aw = max_active_windows
    if aw is not None and aw > num_slots:
        # TopK's fast fold scatters one row per active window offset; more
        # offsets than ring slots would alias (wid % W) and silently drop
        # folds — reject instead (use num_slots, or None for the slow path)
        raise ValueError(
            f"max_active_windows={aw} exceeds num_slots={num_slots}"
        )
    return WSpec(
        window_len=window_len,
        assigner=assigner,
        num_slots=num_slots,
        num_partitions=num_partitions,
        zero_windows=partial(crdts.TopK.zero_windows, num_slots, k),
        fold=(
            (lambda w, s, m, vals, ids, lo: w.fold_windows(s, m, vals, ids, lo=lo, active=aw))
            if aw is not None
            else (lambda w, s, m, vals, ids: w.fold_windows(s, m, vals, ids))
        ),
        read=lambda w, slot: w.window_value(slot),
        max_active_windows=aw,
    )


# ---------------------------------------------------------------------------
# Hash-sharded keyed state (docs/protocol.md §6)
# ---------------------------------------------------------------------------


def _shard_multiplier(num_keys: int) -> int:
    """Largest ``a`` with ``a * num_keys < 2**31`` and ``gcd(a, num_keys) == 1``
    — so ``p(k) = (k * a) % num_keys`` is an i32-safe bijection on [0, C)."""
    import math

    a = max((2**31 - 1) // num_keys, 1)
    while math.gcd(a, num_keys) != 1:
        a -= 1
    return a


@dataclasses.dataclass(frozen=True)
class KeyShards:
    """Hash routing of a keyed domain [0, C) over S owner shards
    (docs/protocol.md §6).

    The "hash" is a multiplicative permutation ``p(k) = (k * mult) % C``
    (bijective because ``gcd(mult, C) == 1``, i32-safe because
    ``mult * C < 2**31`` — jax runs with x64 disabled); ``owner = p % S``
    spreads consecutive (zipf-hot) keys across shards and ``local = p // S``
    is a dense O(1) index into the owner's ``[W, ceil(C/S)]`` key range — no
    per-key hash table.  The inverse (local -> global key, needed by the
    cross-shard top-k read) is the precomputed :meth:`key_table`, shipped as
    a device-sharded input rather than recomputed on device (the modular
    inverse would overflow i32).

    Hashable and static — safe to close over in a jitted dataplane.
    """

    num_keys: int  # C — global keyed domain size
    num_shards: int  # S — owner shards (= mesh data-axis size)
    mult: int = 0  # permutation multiplier; 0 = derive in __post_init__

    def __post_init__(self):
        if self.mult == 0:
            object.__setattr__(self, "mult", _shard_multiplier(self.num_keys))

    @property
    def width(self) -> int:
        """Local key-range size ceil(C/S) — every shard's state is padded to
        this so the sharded WState has one static shape."""
        return -(-self.num_keys // self.num_shards)

    def perm(self, keys: jax.Array) -> jax.Array:
        return (keys.astype(jnp.int32) * jnp.int32(self.mult)) % jnp.int32(self.num_keys)

    def shard_of(self, keys: jax.Array) -> jax.Array:
        """Owner shard id per key (the hash-routing rule)."""
        return self.perm(keys) % jnp.int32(self.num_shards)

    def local_of(self, keys: jax.Array) -> jax.Array:
        """Dense index into the owner's local key range."""
        return self.perm(keys) // jnp.int32(self.num_shards)

    def num_local(self, shard: int) -> int:
        """Real (unpadded) key count of ``shard``'s range."""
        return (self.num_keys - shard + self.num_shards - 1) // self.num_shards

    def key_table(self) -> np.ndarray:
        """u32[S, width] inverse map ``(shard, local) -> global key``; padded
        entries (locals past the shard's real range) carry the sentinel C."""
        C, S = self.num_keys, self.num_shards
        p = (np.arange(C, dtype=np.int64) * self.mult) % C
        inv = np.empty(C, dtype=np.uint32)
        inv[p] = np.arange(C, dtype=np.uint32)
        table = np.full((S, self.width), C, dtype=np.uint32)
        for s in range(S):
            n = self.num_local(s)
            table[s, :n] = inv[s + S * np.arange(n, dtype=np.int64)]
        return table


def wgcounter_sharded(
    window_len: int, num_slots: int, num_partitions: int, shards: KeyShards,
    dtype=jnp.float32, assigner: WindowAssigner | None = None,
) -> WSpec:
    """Keyed grow-only counter over ONE shard's key range
    (docs/protocol.md §6).

    State is ``[W, 1, width]``: the key axis holds only this shard's
    ``ceil(C/S)`` locals, and the actor axis collapses to 1 because folds are
    owner-exclusive — every event for a key is routed to its single owner, so
    no per-actor slots are needed for merge monotonicity (replay idempotence
    still comes from the ``folded`` frontier, which keeps all
    ``num_partitions`` source entries, as does ``progress``).  The generic
    WState machinery (``delta_since``/``merge``/``window_value``) operates on
    this per-key-range state unchanged — a delta ships only the owner's dirty
    slots of its own range.  Fold inputs: ``amounts`` per lane plus ``keys``
    = LOCAL indices (route with :meth:`KeyShards.local_of` first).
    """
    width = shards.width
    return WSpec(
        window_len=window_len,
        assigner=assigner,
        num_slots=num_slots,
        num_partitions=num_partitions,
        zero_windows=partial(
            crdts.GCounter.zero_windows, num_slots, 1, (width,), dtype
        ),
        fold=lambda w, s, m, amounts, keys: w.fold_windows(s, m, 0, amounts, keys),
        read=lambda w, slot: w.window_value(slot),
    )


def shard_topk_read(
    spec: WSpec, state: WState, wid, key_table_row: jax.Array, num_keys: int,
    axis_name: str, k: int = 1,
):
    """Cross-shard top-k window read over a sharded keyed counter — no full
    gather (docs/protocol.md §6).

    Each shard reduces its own ``[width]`` key range to k ``(count, key)``
    candidates (padded locals masked via the ``key_table_row`` sentinel),
    the ``[S, k]`` candidate sets ride one small ``all_gather``, and the
    global top-k is selected by (count desc, key asc).  ``k=1`` reproduces
    ``jnp.argmax`` over the unsharded count vector exactly: ties break to
    the lowest GLOBAL key id (not local index — the routing permutation is
    not monotone).  Returns ``((counts f32[k], keys u32[k]), ok)``; ``ok``
    requires the window complete and unevicted on every shard.
    """
    counts, ok = window_value(spec, state, wid)
    live = key_table_row < jnp.uint32(num_keys)
    sentinel_key = jnp.uint32(num_keys)
    if k == 1:
        masked = jnp.where(live, counts, -jnp.inf)
        cmax = jnp.max(masked)
        ckey = jnp.min(jnp.where(masked == cmax, key_table_row, sentinel_key))
        cand_c = lax.all_gather(cmax, axis_name)  # [S]
        cand_k = lax.all_gather(ckey, axis_name)
        gmax = jnp.max(cand_c)
        gkey = jnp.min(jnp.where(cand_c == gmax, cand_k, sentinel_key))
        top = (gmax[None], gkey[None])
    else:
        masked = jnp.where(live, counts, -jnp.inf)
        cv, ci = lax.top_k(masked, k)
        ck = jnp.where(cv > -jnp.inf, key_table_row[ci], sentinel_key)
        cand_v = lax.all_gather(cv, axis_name).reshape(-1)  # [S*k]
        cand_k = lax.all_gather(ck, axis_name).reshape(-1)
        # (count desc, key asc): sort ascending on the negated count first
        sv, sk = lax.sort((-cand_v, cand_k), dimension=0, num_keys=2)
        top = (-sv[:k], sk[:k])
    ok = jnp.min(lax.all_gather(ok.astype(jnp.int32), axis_name)) > 0
    return top, ok


def wgset(
    window_len: int, num_slots: int, num_partitions: int, domain: int,
    assigner: WindowAssigner | None = None,
) -> WSpec:
    return WSpec(
        window_len=window_len,
        assigner=assigner,
        num_slots=num_slots,
        num_partitions=num_partitions,
        zero_windows=partial(crdts.GSet.zero_windows, num_slots, domain),
        fold=lambda w, s, m, elems: w.fold_windows(s, m, elems),
        read=lambda w, slot: w.window_value(slot),
    )
