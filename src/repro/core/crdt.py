"""CRDT catalog — state-based (convergent) replicated data types as JAX pytrees.

Every CRDT here is a join-semilattice: ``merge`` is commutative, associative
and idempotent (property-tested in tests/test_crdt_laws.py).  Design rules:

* State is dense arrays — maps keyed by node become fixed ``[num_actors]``
  slot vectors so merges vectorize and ride collectives (see lattice.py).
* Each class also provides *windowed* folds: the same CRDT stored with a
  leading ``[W]`` ring-slot axis, updated from a batch of timestamped events
  in one vectorized scatter (this is what the Pallas ``window_agg`` kernel
  accelerates on TPU).
* Grow-only slot counters require per-actor monotonicity: only actor ``p``
  writes slot ``p``, and contributions are non-negative (PN pairs handle
  signed values).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.lattice import (
    Reduce,
    float_to_ordered_u32,
    lattice_dataclass,
    lex_join,
    ordered_u32_to_float,
)

NEG_INF = jnp.float32(-jnp.inf)


def _masked(vals: jax.Array, mask: jax.Array, fill) -> jax.Array:
    return jnp.where(mask, vals, jnp.asarray(fill, vals.dtype))


# ---------------------------------------------------------------------------
# GCounter — grow-only counter (optionally keyed, e.g. per Nexmark category).
# ---------------------------------------------------------------------------


@lattice_dataclass(slots=Reduce.MAX)
class GCounter:
    """slots[actor, *key_shape]; merge = elementwise max; value = sum(actors)."""

    slots: jax.Array

    @classmethod
    def zero(cls, num_actors: int, key_shape: tuple[int, ...] = (), dtype=jnp.float32):
        return cls(slots=jnp.zeros((num_actors, *key_shape), dtype=dtype))

    def add(self, actor, amount, key=None) -> "GCounter":
        """Add non-negative ``amount`` to this actor's slot (optionally keyed)."""
        if key is None:
            return GCounter(self.slots.at[actor].add(amount))
        return GCounter(self.slots.at[actor, key].add(amount))

    @property
    def value(self) -> jax.Array:
        return jnp.sum(self.slots, axis=0)

    # -- windowed ------------------------------------------------------------
    @classmethod
    def zero_windows(cls, W: int, num_actors: int, key_shape=(), dtype=jnp.float32):
        return cls(slots=jnp.zeros((W, num_actors, *key_shape), dtype=dtype))

    def fold_windows(
        self, slot_ids: jax.Array, mask: jax.Array, actor, amounts: jax.Array,
        keys: jax.Array | None = None,
    ) -> "GCounter":
        amounts = _masked(amounts.astype(self.slots.dtype), mask, 0)
        if keys is None:
            new = self.slots.at[slot_ids, actor].add(amounts)
        else:
            new = self.slots.at[slot_ids, actor, keys].add(amounts)
        return GCounter(new)

    def window_value(self, slot) -> jax.Array:
        return jnp.sum(self.slots[slot], axis=0)


@lattice_dataclass(pos=Reduce.MAX, neg=Reduce.MAX)
class PNCounter:
    """Positive/negative GCounter pair — supports signed updates."""

    pos: jax.Array
    neg: jax.Array

    @classmethod
    def zero(cls, num_actors: int, key_shape: tuple[int, ...] = (), dtype=jnp.float32):
        z = jnp.zeros((num_actors, *key_shape), dtype=dtype)
        return cls(pos=z, neg=z)

    def add(self, actor, amount, key=None) -> "PNCounter":
        up = jnp.maximum(amount, 0)
        dn = jnp.maximum(-amount, 0)
        if key is None:
            return PNCounter(self.pos.at[actor].add(up), self.neg.at[actor].add(dn))
        return PNCounter(
            self.pos.at[actor, key].add(up), self.neg.at[actor, key].add(dn)
        )

    @property
    def value(self) -> jax.Array:
        return jnp.sum(self.pos, axis=0) - jnp.sum(self.neg, axis=0)

    @classmethod
    def zero_windows(cls, W: int, num_actors: int, key_shape=(), dtype=jnp.float32):
        z = jnp.zeros((W, num_actors, *key_shape), dtype=dtype)
        return cls(pos=z, neg=z)

    def fold_windows(self, slot_ids, mask, actor, amounts, keys=None) -> "PNCounter":
        amounts = _masked(amounts.astype(self.pos.dtype), mask, 0)
        up, dn = jnp.maximum(amounts, 0), jnp.maximum(-amounts, 0)
        if keys is None:
            return PNCounter(
                self.pos.at[slot_ids, actor].add(up),
                self.neg.at[slot_ids, actor].add(dn),
            )
        return PNCounter(
            self.pos.at[slot_ids, actor, keys].add(up),
            self.neg.at[slot_ids, actor, keys].add(dn),
        )

    def window_value(self, slot) -> jax.Array:
        return jnp.sum(self.pos[slot], axis=0) - jnp.sum(self.neg[slot], axis=0)


# ---------------------------------------------------------------------------
# Max / Min registers.
# ---------------------------------------------------------------------------


@lattice_dataclass(v=Reduce.MAX)
class MaxReg:
    v: jax.Array

    @classmethod
    def zero(cls, key_shape: tuple[int, ...] = (), dtype=jnp.float32):
        return cls(v=jnp.full(key_shape, -jnp.inf, dtype=dtype))

    def insert(self, x, key=None) -> "MaxReg":
        if key is None:
            return MaxReg(jnp.maximum(self.v, x))
        return MaxReg(self.v.at[key].max(x))

    @property
    def value(self) -> jax.Array:
        return self.v

    @classmethod
    def zero_windows(cls, W: int, key_shape=(), dtype=jnp.float32):
        return cls(v=jnp.full((W, *key_shape), -jnp.inf, dtype=dtype))

    def fold_windows(self, slot_ids, mask, vals, keys=None) -> "MaxReg":
        vals = _masked(vals.astype(self.v.dtype), mask, -jnp.inf)
        if keys is None:
            return MaxReg(self.v.at[slot_ids].max(vals))
        return MaxReg(self.v.at[slot_ids, keys].max(vals))

    def window_value(self, slot) -> jax.Array:
        return self.v[slot]


@lattice_dataclass(v=Reduce.MIN)
class MinReg:
    v: jax.Array

    @classmethod
    def zero(cls, key_shape: tuple[int, ...] = (), dtype=jnp.float32):
        return cls(v=jnp.full(key_shape, jnp.inf, dtype=dtype))

    def insert(self, x, key=None) -> "MinReg":
        if key is None:
            return MinReg(jnp.minimum(self.v, x))
        return MinReg(self.v.at[key].min(x))

    @property
    def value(self) -> jax.Array:
        return self.v

    @classmethod
    def zero_windows(cls, W: int, key_shape=(), dtype=jnp.float32):
        return cls(v=jnp.full((W, *key_shape), jnp.inf, dtype=dtype))

    def fold_windows(self, slot_ids, mask, vals, keys=None) -> "MinReg":
        vals = _masked(vals.astype(self.v.dtype), mask, jnp.inf)
        if keys is None:
            return MinReg(self.v.at[slot_ids].min(vals))
        return MinReg(self.v.at[slot_ids, keys].min(vals))

    def window_value(self, slot) -> jax.Array:
        return self.v[slot]


# ---------------------------------------------------------------------------
# G-Set over a bounded domain (bitmap).
# ---------------------------------------------------------------------------


@lattice_dataclass(bits=Reduce.OR)
class GSet:
    bits: jax.Array  # u8[domain] (0/1; uint8 so scatter-max == or)

    @classmethod
    def zero(cls, domain: int):
        return cls(bits=jnp.zeros((domain,), dtype=jnp.uint8))

    def insert(self, elem) -> "GSet":
        return GSet(self.bits.at[elem].set(jnp.uint8(1)))

    @property
    def value(self) -> jax.Array:
        return self.bits.astype(jnp.bool_)

    @property
    def size(self) -> jax.Array:
        return jnp.sum(self.bits.astype(jnp.int32))

    @classmethod
    def zero_windows(cls, W: int, domain: int):
        return cls(bits=jnp.zeros((W, domain), dtype=jnp.uint8))

    def fold_windows(self, slot_ids, mask, elems) -> "GSet":
        # scatter-or == scatter-max on {0,1} uint8
        return GSet(self.bits.at[slot_ids, elems].max(mask.astype(jnp.uint8)))

    def window_value(self, slot) -> jax.Array:
        return self.bits[slot].astype(jnp.bool_)


# ---------------------------------------------------------------------------
# LWW register — lexicographic (ts, payload) lattice; custom merge.
# ---------------------------------------------------------------------------


@lattice_dataclass(ts="custom", val="custom")
class LWWReg:
    ts: jax.Array  # i32[*key_shape]
    val: jax.Array  # ordered-u32 payload

    @classmethod
    def zero(cls, key_shape: tuple[int, ...] = ()):
        return cls(
            ts=jnp.full(key_shape, -(2**31), dtype=jnp.int32),
            val=jnp.zeros(key_shape, dtype=jnp.uint32),
        )

    def merge(self, other: "LWWReg") -> "LWWReg":
        ts, val = lex_join(self.ts, self.val, other.ts, other.val)
        return LWWReg(ts, val)

    def set_float(self, ts, x, key=None) -> "LWWReg":
        u = float_to_ordered_u32(jnp.asarray(x, jnp.float32))
        return self._set(ts, u, key)

    def set_u32(self, ts, x, key=None) -> "LWWReg":
        return self._set(ts, jnp.asarray(x, jnp.uint32), key)

    def _set(self, ts, u, key) -> "LWWReg":
        ts = jnp.asarray(ts, jnp.int32)
        if key is None:
            nts, nval = lex_join(self.ts, self.val, ts, u)
            return LWWReg(nts, nval)
        nts, nval = lex_join(self.ts[key], self.val[key], ts, u)
        return LWWReg(self.ts.at[key].set(nts), self.val.at[key].set(nval))

    @property
    def value_float(self) -> jax.Array:
        return ordered_u32_to_float(self.val)

    @property
    def value_u32(self) -> jax.Array:
        return self.val


# ---------------------------------------------------------------------------
# Bounded Top-K (set semantics) — Q7 "highest bids" lattice.
# ---------------------------------------------------------------------------


def _topk_join_sorted(vals_a, ids_a, vals_b, ids_b, k: int):
    """Join two top-k sets (desc-sorted, -inf padded) into the top-k union.

    Set semantics: exact (val, id) duplicates collapse, so the join is
    idempotent.  Uses lax.sort with two keys for lexicographic order.
    """
    vals = jnp.concatenate([vals_a, vals_b], axis=-1)
    ids = jnp.concatenate([ids_a, ids_b], axis=-1)
    # ascending lexicographic sort by (val, id)
    svals, sids = lax.sort((vals, ids), dimension=-1, num_keys=2)
    # mark duplicates of their left neighbour
    dup = jnp.zeros(svals.shape, dtype=bool)
    dup = dup.at[..., 1:].set(
        (svals[..., 1:] == svals[..., :-1]) & (sids[..., 1:] == sids[..., :-1])
    )
    svals = jnp.where(dup, -jnp.inf, svals)
    sids = jnp.where(dup, 0, sids)
    svals, sids = lax.sort((svals, sids), dimension=-1, num_keys=2)
    # top-k = last k ascending, reversed to descending
    top_v = svals[..., -k:][..., ::-1]
    top_i = sids[..., -k:][..., ::-1]
    return top_v, top_i


@lattice_dataclass(vals="custom", ids="custom")
class TopK:
    """Top-k (value, id) pairs, descending, padded with (-inf, 0)."""

    vals: jax.Array  # f32[..., k]
    ids: jax.Array  # u32[..., k]

    @classmethod
    def zero(cls, k: int, key_shape: tuple[int, ...] = ()):
        return cls(
            vals=jnp.full((*key_shape, k), -jnp.inf, dtype=jnp.float32),
            ids=jnp.zeros((*key_shape, k), dtype=jnp.uint32),
        )

    @property
    def k(self) -> int:
        return self.vals.shape[-1]

    def merge(self, other: "TopK") -> "TopK":
        v, i = _topk_join_sorted(self.vals, self.ids, other.vals, other.ids, self.k)
        return TopK(v, i)

    def insert_batch(self, vals: jax.Array, ids: jax.Array, mask: jax.Array) -> "TopK":
        vals = _masked(vals.astype(jnp.float32), mask, -jnp.inf)
        ids = jnp.where(mask, ids.astype(jnp.uint32), 0)
        v, i = _topk_join_sorted(self.vals, self.ids, vals, ids, self.k)
        return TopK(v, i)

    @property
    def value(self) -> tuple[jax.Array, jax.Array]:
        return self.vals, self.ids

    # -- windowed ------------------------------------------------------------
    @classmethod
    def zero_windows(cls, W: int, k: int):
        return cls(
            vals=jnp.full((W, k), -jnp.inf, dtype=jnp.float32),
            ids=jnp.zeros((W, k), dtype=jnp.uint32),
        )

    def fold_windows(self, slot_ids, mask, vals, ids, lo=None, active: int = 8) -> "TopK":
        """Per-window top-k fold of a batch.

        Fast path (``lo`` given, from WSpec.max_active_windows): a partition-
        ordered batch spans only a few windows, so fold just ``active`` window
        offsets starting at the batch's lowest window id — per offset, a
        ``lax.top_k`` pre-reduction of the batch then a tiny 2k-sorted join.
        This is the jnp analogue of the Pallas ``topk_window`` kernel.
        Fallback: masked join vmapped over every ring slot.
        """
        W = self.vals.shape[0]
        vals = vals.astype(jnp.float32)
        ids = ids.astype(jnp.uint32)
        k = self.k

        if lo is None:
            def per_slot(w, sv, si):
                m = mask & (slot_ids == w)
                bv = jnp.where(m, vals, -jnp.inf)
                bi = jnp.where(m, ids, 0)
                return _topk_join_sorted(sv, si, bv, bi, k)

            v, i = jax.vmap(per_slot)(jnp.arange(W), self.vals, self.ids)
            return TopK(v, i)

        wid_of_slot = lo + jnp.arange(active, dtype=jnp.int32)
        slots = wid_of_slot % W

        def per_off(w, slot):
            m = mask & (slot_ids == slot) & (w >= 0)
            bv = jnp.where(m, vals, -jnp.inf)
            # pre-reduce the batch to its top-k, then a 2k set-join
            tv, ti = lax.top_k(bv, k)
            tids = jnp.where(tv > -jnp.inf, ids[ti], 0)
            return _topk_join_sorted(self.vals[slot], self.ids[slot], tv, tids, k)

        v, i = jax.vmap(per_off)(wid_of_slot, slots)
        # offsets map to distinct slots (active <= W); scatter rows back
        return TopK(self.vals.at[slots].set(v), self.ids.at[slots].set(i))

    def window_value(self, slot) -> tuple[jax.Array, jax.Array]:
        return self.vals[slot], self.ids[slot]


CRDT = Any  # any of the classes above
