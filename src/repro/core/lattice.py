"""Join-semilattice machinery shared by every CRDT.

The paper's CRDTs synchronize by gossip merges (Akka Distributed Data).  On a
TPU pod the natural analogue is a *collective*: a CRDT whose merge decomposes
into elementwise MAX / MIN / OR reductions can be joined across all replicas
with a single ``jax.lax.pmax``-style all-reduce — the ICI ring *is* the gossip
round.  This module defines the per-leaf reduce vocabulary, generic pairwise /
N-way merges, and the order-preserving packings that let non-elementwise
lattices (LWW registers over floats) ride a MAX reduction anyway.
"""
from __future__ import annotations

import dataclasses
import enum
import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax


class Reduce(enum.Enum):
    """Elementwise lattice join kind for one pytree leaf."""

    MAX = "max"
    MIN = "min"
    OR = "or"  # boolean / bitmask join


def join_leaf(kind: Reduce, a: jax.Array, b: jax.Array) -> jax.Array:
    if kind is Reduce.MAX:
        return jnp.maximum(a, b)
    if kind is Reduce.MIN:
        return jnp.minimum(a, b)
    if kind is Reduce.OR:
        if a.dtype == jnp.bool_:
            return jnp.logical_or(a, b)
        return jnp.bitwise_or(a, b)
    raise ValueError(f"unknown reduce kind {kind}")


def axis_reduce_leaf(kind: Reduce, x: jax.Array, axis_name: str) -> jax.Array:
    """Collective lattice join across a mesh axis (inside shard_map)."""
    if kind is Reduce.MAX:
        return lax.pmax(x, axis_name)
    if kind is Reduce.MIN:
        return lax.pmin(x, axis_name)
    if kind is Reduce.OR:
        if x.dtype == jnp.bool_:
            return lax.pmax(x.astype(jnp.uint8), axis_name).astype(jnp.bool_)
        # bitwise-or all-reduce: decompose into pmax per bit is wasteful; use
        # all_gather + fold (single collective, log-depth fold is free compute).
        g = lax.all_gather(x, axis_name)
        return functools.reduce(jnp.bitwise_or, [g[i] for i in range(g.shape[0])])
    raise ValueError(f"unknown reduce kind {kind}")


# ---------------------------------------------------------------------------
# Lattice-dataclass registry: each CRDT dataclass declares, per field, how the
# field joins.  ``None`` marks a static/meta field (not merged, not a leaf).
# ---------------------------------------------------------------------------

_LATTICE_FIELDS: dict[type, dict[str, Reduce | str]] = {}


def lattice_dataclass(cls=None, /, **field_kinds):
    """Register ``cls`` as a frozen pytree dataclass with per-field joins.

    field_kinds maps field name -> Reduce | "custom" (handled by cls.merge)
    Fields not listed are treated as pytree data that custom merge handles.
    """

    def wrap(c):
        c = dataclasses.dataclass(frozen=True)(c)
        names = [f.name for f in dataclasses.fields(c)]
        jax.tree_util.register_dataclass(c, data_fields=names, meta_fields=[])
        _LATTICE_FIELDS[c] = dict(field_kinds)
        return c

    if cls is not None:
        return wrap(cls)
    return wrap


def field_kinds(obj_or_cls) -> dict[str, Reduce | str]:
    cls = obj_or_cls if isinstance(obj_or_cls, type) else type(obj_or_cls)
    return _LATTICE_FIELDS[cls]


def join(a, b):
    """Generic pairwise lattice join.

    Dispatches to ``a.merge(b)`` when the class defines one (non-elementwise
    lattices), else joins field-by-field per the registered reduce kinds.
    """
    if hasattr(a, "merge"):
        return a.merge(b)
    return elementwise_join(a, b)


def elementwise_join(a, b):
    kinds = field_kinds(a)
    out = {}
    for name, kind in kinds.items():
        va, vb = getattr(a, name), getattr(b, name)
        if isinstance(kind, Reduce):
            out[name] = jax.tree.map(lambda x, y, k=kind: join_leaf(k, x, y), va, vb)
        else:
            raise ValueError(f"field {name} needs custom merge")
    return type(a)(**out)


def join_many(states: Sequence[Any], merge_fn: Callable[[Any, Any], Any] | None = None):
    """Log-depth fold of N replica states with an associative join."""
    merge_fn = merge_fn or join
    xs = list(states)
    if not xs:
        raise ValueError("join_many of empty sequence")
    while len(xs) > 1:
        nxt = [merge_fn(xs[i], xs[i + 1]) for i in range(0, len(xs) - 1, 2)]
        if len(xs) % 2 == 1:
            nxt.append(xs[-1])
        xs = nxt
    return xs[0]


def join_stacked(stacked, merge_fn: Callable[[Any, Any], Any] | None = None):
    """Join a pytree whose leaves carry a leading replica axis (from all_gather).

    Log-depth halving so the collective-join path costs O(log R) vectorized
    merges instead of an O(R) sequential fold.
    """
    merge_fn = merge_fn or join
    n = jax.tree.leaves(stacked)[0].shape[0]
    vmerge = jax.vmap(merge_fn)

    def half(t, lo, hi):
        return jax.tree.map(lambda x: x[lo:hi], t)

    cur = stacked
    while n > 1:
        k = n // 2
        merged = vmerge(half(cur, 0, k), half(cur, k, 2 * k))
        if n % 2 == 1:
            tail = half(cur, 2 * k, n)
            cur = jax.tree.map(lambda m, t: jnp.concatenate([m, t], axis=0), merged, tail)
            n = k + 1
        else:
            cur = merged
            n = k
    return jax.tree.map(lambda x: x[0] if x.ndim and x.shape[0] == 1 else x, cur)


def axis_join(state, axis_name: str):
    """Collective lattice join across ``axis_name`` for a registered lattice.

    Elementwise lattices use p{max,min} directly (true all-reduce).  Custom
    lattices fall back to all_gather + log-depth vectorized fold.
    """
    kinds = field_kinds(state)
    if all(isinstance(k, Reduce) for k in kinds.values()) and not hasattr(state, "merge"):
        out = {}
        for name, kind in kinds.items():
            out[name] = jax.tree.map(
                lambda x, k=kind: axis_reduce_leaf(k, x, axis_name), getattr(state, name)
            )
        return type(state)(**out)
    gathered = jax.tree.map(lambda x: lax.all_gather(x, axis_name), state)
    return join_stacked(gathered, merge_fn=join)


# ---------------------------------------------------------------------------
# Order-preserving packings: let lexicographic lattices (LWW, arg-max) ride a
# plain MAX reduction.
# ---------------------------------------------------------------------------


def float_to_ordered_u32(x: jax.Array) -> jax.Array:
    """Monotone bijection f32 -> u32: a<b  <=>  f(a)<f(b) (IEEE754 trick)."""
    bits = lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    sign = bits >> 31
    return jnp.where(sign == 1, ~bits, bits | jnp.uint32(0x80000000))


def ordered_u32_to_float(u: jax.Array) -> jax.Array:
    sign = u >> 31
    bits = jnp.where(sign == 0, ~u, u & jnp.uint32(0x7FFFFFFF))
    return lax.bitcast_convert_type(bits.astype(jnp.uint32), jnp.float32)


def lex_join(
    ts_a: jax.Array, val_a: jax.Array, ts_b: jax.Array, val_b: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Lexicographic (timestamp, payload) join — the LWW-register lattice.

    Larger timestamp wins; ties broken deterministically by larger payload
    (ordered-u32 compare), so the join is commutative, associative, and
    idempotent without needing a 64-bit packing (works with x64 disabled).
    """
    a_wins = (ts_a > ts_b) | ((ts_a == ts_b) & (val_a >= val_b))
    return jnp.where(a_wins, ts_a, ts_b), jnp.where(a_wins, val_a, val_b)
