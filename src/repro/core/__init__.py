# The paper's primary contribution: CRDT lattices + Windowed CRDTs
# (Algorithm 1) with watermark-gated deterministic reads, plus the
# lattice-collective machinery that replaces gossip on a TPU mesh.
from repro.core.lattice import (
    Reduce,
    axis_join as lattice_axis_join,
    elementwise_join,
    float_to_ordered_u32,
    join,
    join_many,
    join_stacked,
    lattice_dataclass,
    lex_join,
    ordered_u32_to_float,
)
from repro.core.crdt import (
    GCounter,
    GSet,
    LWWReg,
    MaxReg,
    MinReg,
    PNCounter,
    TopK,
)
from repro.core.window import (
    Hopping,
    Tumbling,
    WindowAssigner,
    as_assigner,
    expand_events,
)
from repro.core.wcrdt import (
    WSpec,
    WState,
    axis_join,
    axis_join_aligned,
    baseline_of,
    delta_axis_join,
    delta_nbytes,
    delta_since,
    global_watermark,
    increment_watermark,
    insert,
    merge,
    merge_delta_stack,
    state_nbytes,
    zero_baseline,
    wgcounter,
    wgset,
    window_complete,
    window_value,
    wmaxreg,
    wminreg,
    wpncounter,
    wtopk,
)

__all__ = [k for k in dir() if not k.startswith("_")]
