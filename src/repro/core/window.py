"""First-class window assigners (DESIGN.md §8).

The paper defines Windowed CRDTs over tumbling windows, where window
membership is the integer division ``ts // window_len``.  This module lifts
that implicit rule into a small, hashable abstraction so the same WCRDT
machinery (ring slots, watermark-gated reads, delta sync) serves overlapping
sliding/hopping windows — the hard case for scalable multiway aggregation
(Gulisano et al.; see PAPERS.md) and what Nexmark Q5 "hot items" needs.

An assigner answers three questions, each a pure function of static config:

* ``assign(ts)``   — which window ids does an event at ``ts`` belong to?
                     Up to ``windows_per_event`` ids (K), plus a validity
                     mask (early events near the stream start belong to
                     fewer than K windows).
* ``end_ts(wid)``  — when does window ``wid`` close?  Completion is
                     ``gwm >= end_ts(wid)``, exactly the paper's rule with
                     the tumbling extent generalized.
* ``first_dirty_wid(frontier_ts)`` — the smallest window id any event with
                     ``ts >= frontier_ts`` can land in.  This is the delta
                     dirty rule's generalization (docs/protocol.md §2): a
                     ring slot is dirty iff its tenant wid reaches this.

Every per-window aggregate remains a join-semilattice, so determinism and
convergence carry over unchanged (Preguiça; see PAPERS.md): overlap only
multiplies *assignment*, never the merge algebra.  All methods are written
with plain operators so they work identically on Python ints (runtime
emission loops) and traced jnp arrays (the jitted dataplane); jnp floor
division matches Python's for the negative intermediate in
``first_dirty_wid``.

``Tumbling(window_len)`` is ``Hopping(window_len, hop=window_len)`` (K=1)
and reproduces the pre-assigner behavior bit-for-bit: ``insert`` keeps the
single-lane fold graph, and every formula below degenerates to the old
``ts // window_len`` arithmetic.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Hopping:
    """Overlapping windows of length ``window_len`` starting every ``hop``.

    Window ``w`` covers ``[w*hop, w*hop + window_len)``; each event belongs
    to ``window_len // hop`` consecutive windows (fewer near the stream
    start, where some of them would begin before t=0).  ``hop`` must divide
    ``window_len`` so that K is static — the fold expands each event into a
    fixed K lanes (see ``expand_events``), which is what keeps the scatter
    vectorized and jit-able.
    """

    window_len: int
    hop: int

    def __post_init__(self):
        if self.hop <= 0 or self.window_len <= 0:
            raise ValueError(f"window_len and hop must be positive: {self}")
        if self.hop > self.window_len:
            raise ValueError(f"hop must not exceed window_len: {self}")
        if self.window_len % self.hop:
            raise ValueError(f"hop must divide window_len: {self}")

    # ---- static shape ------------------------------------------------------
    @property
    def windows_per_event(self) -> int:
        """K: the number of windows an (interior) event belongs to."""
        return self.window_len // self.hop

    # ---- assignment --------------------------------------------------------
    def assign(self, ts) -> tuple[jax.Array, jax.Array]:
        """Window ids of each event: ``(wids, valid)`` with trailing ``[K]``.

        ``wids[..., 0]`` is the newest window containing the event (the one
        the tumbling rule would pick when K=1); older overlapping windows
        follow.  ``valid`` masks ids that would start before t=0.
        """
        ts = jnp.asarray(ts).astype(jnp.int32)
        hi = ts // jnp.int32(self.hop)
        offs = jnp.arange(self.windows_per_event, dtype=jnp.int32)
        wids = hi[..., None] - offs
        return wids, wids >= 0

    def window_of(self, ts):
        """The newest window containing ``ts`` (== the tumbling wid at K=1)."""
        return ts // self.hop if isinstance(ts, int) else (
            jnp.asarray(ts).astype(jnp.int32) // jnp.int32(self.hop)
        )

    def contains(self, wid, ts):
        """Membership predicate — the oracle-side mirror of ``assign``."""
        start = wid * self.hop
        return (ts >= start) & (ts < start + self.window_len)

    # ---- extents -----------------------------------------------------------
    def start_ts(self, wid):
        return wid * self.hop

    def end_ts(self, wid):
        return wid * self.hop + self.window_len

    def complete(self, wid, gwm):
        """Paper §3.3 read gate with the window extent generalized: final
        (and identical on every replica) once the global watermark passes
        the window's end."""
        return gwm >= self.end_ts(wid)

    def first_dirty_wid(self, frontier_ts):
        """Smallest wid any event with ``ts >= frontier_ts`` can land in.

        A window receives an event iff it contains it, i.e. iff its end
        lies strictly beyond the event's ts — so the candidate set is
        ``{w : end_ts(w) > frontier_ts}``, whose minimum is
        ``floor((frontier_ts - window_len) / hop) + 1`` (floor division,
        exact for the negative intermediate near the stream start; clamped
        at 0).  For tumbling this is ``frontier_ts // window_len`` — the
        original delta dirty rule (docs/protocol.md §2)."""
        w = (frontier_ts - self.window_len) // self.hop + 1
        if isinstance(w, jax.Array):
            return jnp.maximum(w, 0)
        return max(int(w), 0)


@dataclasses.dataclass(frozen=True)
class Tumbling(Hopping):
    """Non-overlapping windows — ``Hopping(window_len, window_len)``; K=1.

    Construct as ``Tumbling(window_len)``; the hop is pinned to the window
    length so the assignment degenerates to ``ts // window_len`` and the
    fold keeps today's single-lane graph bit-for-bit."""

    hop: int = 0  # sentinel; pinned to window_len in __post_init__

    def __post_init__(self):
        if self.hop == 0:
            object.__setattr__(self, "hop", self.window_len)
        if self.hop != self.window_len:
            raise ValueError("Tumbling windows have hop == window_len; "
                             f"got {self} — use Hopping for overlap")
        super().__post_init__()


# Anything quacking like Hopping (the structural protocol WSpec carries).
WindowAssigner = Hopping


def as_assigner(window_len: int, hop: int | None = None) -> WindowAssigner:
    """Normalize a (window_len, hop) pair: ``hop in (None, 0, window_len)``
    means tumbling; anything else is a hopping/sliding window."""
    if hop is None or hop == 0 or hop == window_len:
        return Tumbling(window_len)
    return Hopping(window_len, hop)


def expand_events(
    assigner: WindowAssigner, ts: jax.Array, mask: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Expand ``[B]`` events into the ``[B*K]`` multi-emit lane stream.

    Lane ``b*K + k`` carries event ``b``'s k-th (newest-first) window id;
    lanes whose window starts before t=0 are masked out.  Payload arrays
    follow with ``jnp.repeat(x, K)`` — the fold kernels (kernels/window_agg)
    are agnostic to whether lanes came from distinct events or one event
    multi-emitted, which is the whole trick: overlap costs K× lanes, not a
    new kernel."""
    wids, in_win = assigner.assign(ts)
    wid_flat = wids.reshape(-1)
    mask_flat = (mask[..., None] & in_win).reshape(-1)
    return wid_flat, mask_flat
