"""Pixtral-12B backbone: mistral-nemo decoder; pixtral-ViT frontend is a stub
supplying 1024 patch embeddings [hf:mistralai/Pixtral-12B-2409; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131_072,
    head_dim=128,
    frontend="vision",
    frontend_prefix=1024,
)
