"""Qwen3-MoE 235B-A22B: 128 experts top-8, every layer MoE [hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,          # per-expert hidden width (assignment lists d_ff=1536)
    vocab=151_936,
    head_dim=128,
    moe_experts=128,
    moe_top_k=8,
    moe_d_ff=1536,
    moe_every=1,
)
