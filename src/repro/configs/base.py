"""Architecture configuration schema + the input-shape cells.

One ``ArchConfig`` per assigned architecture lives in
``src/repro/configs/<id>.py``; reduced variants for CPU smoke tests come from
``ArchConfig.reduced()``.
"""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "audio", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- MoE ---
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden width
    moe_every: int = 1  # every k-th layer is MoE (llama4: interleaved)
    moe_shared_expert: bool = False
    capacity_factor: float = 1.25

    # --- SSM (mamba) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_version: int = 1  # 1 = mamba1 (falcon-mamba), 2 = mamba2 (zamba2)
    ssm_heads: int = 0  # mamba2 value heads (0 -> d_inner // 64)

    # --- hybrid (zamba2): one shared-weight attention block applied every
    # ``hybrid_attn_every`` mamba layers ---
    hybrid_attn_every: int = 0

    # --- encoder-decoder (seamless) ---
    enc_layers: int = 0
    dec_layers: int = 0

    # --- attention ---
    sliding_window: int = 0  # 0 = full causal
    long_context_window: int = 4096  # window used by hybrid attn at 500k
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- modality frontend stubs ---
    frontend: Literal["none", "audio", "vision"] = "none"
    frontend_prefix: int = 0  # embedding lanes supplied by the stub

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_enc_dec(self) -> bool:
        return self.enc_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """long_500k runs only for sub-quadratic families (DESIGN.md §6)."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=max(2, min(4, self.n_layers)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) or 4,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            moe_experts=min(self.moe_experts, 8),
            moe_top_k=min(self.moe_top_k, 2),
            moe_d_ff=128 if self.moe_d_ff else 0,
            ssm_state=min(self.ssm_state, 16),
            ssm_heads=4 if self.family in ("hybrid",) else 0,
            enc_layers=2 if self.enc_layers else 0,
            dec_layers=2 if self.dec_layers else 0,
            hybrid_attn_every=3 if self.hybrid_attn_every else 0,
            frontend_prefix=min(self.frontend_prefix, 16),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            long_context_window=256,
        )


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input-shape × step-kind) cell of the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def runnable_cells(cfg: ArchConfig) -> list[str]:
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        cells.append("long_500k")
    return cells
