"""SeamlessM4T-large-v2 backbone: enc-dec transformer [arXiv:2308.11596; hf].

Assignment lists 24L; realized as 24 encoder + 24 decoder layers (the
speech-encoder/text-decoder split of the published model).  The audio
frontend is a stub: input_specs() supplies precomputed frame embeddings.
Decoder length for each shape cell is seq_len // 4 (documented in DESIGN.md).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256_206,
    enc_layers=24,
    dec_layers=24,
    frontend="audio",
)
