"""Zamba2-7B: Mamba2 backbone + shared attention block [arXiv:2411.15242; unverified].

81 mamba2 layers; one shared-weight attention+MLP block applied after every
6th mamba layer (13 applications + 3 tail mamba layers).  long_500k uses a
sliding-window ring cache for the shared attention (DESIGN.md §6).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32_000,
    head_dim=112,
    ssm_state=64,
    ssm_version=2,
    ssm_heads=112,       # d_inner 7168 / 64
    hybrid_attn_every=6,
    long_context_window=4096,
)
