"""Llama-4-Scout 17B-A16E: interleaved MoE, 16 experts top-1, shared expert
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,          # dense + shared-expert FFN width
    vocab=202_048,
    head_dim=128,
    moe_experts=16,
    moe_top_k=1,
    moe_d_ff=8192,
    moe_every=2,        # llama4 interleaves dense / MoE layers
    moe_shared_expert=True,
)
