"""DeepSeek-7B: llama-arch dense [arXiv:2401.02954; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=102_400,
)
