"""Assigned-architecture registry: ``get_config(name)`` / ``list_archs()``."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, SHAPES, ShapeCell, runnable_cells

ARCHS = [
    "minitron_4b",
    "deepseek_7b",
    "deepseek_coder_33b",
    "mistral_large_123b",
    "llama4_scout_17b_a16e",
    "qwen3_moe_235b_a22b",
    "zamba2_7b",
    "falcon_mamba_7b",
    "seamless_m4t_large_v2",
    "pixtral_12b",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def get_config(name: str) -> ArchConfig:
    mod_name = _ALIASES.get(name, name).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def list_archs() -> list[str]:
    return list(ARCHS)


__all__ = ["ArchConfig", "SHAPES", "ShapeCell", "runnable_cells", "get_config", "list_archs", "ARCHS"]
