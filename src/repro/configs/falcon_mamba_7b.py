"""Falcon-Mamba-7B: pure Mamba1, attention-free [arXiv:2410.05355; unverified]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,           # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,              # no FFN; mamba block carries the capacity
    vocab=65_024,
    head_dim=64,
    ssm_state=16,
    ssm_version=1,
)
