"""Decentralized checkpointing — Algorithm 2's ``sometimes do storage.put``
applied to training state.

Every worker persists its own shard ``(step, params, opt, metrics, data_idx)``
on its own schedule; no barrier, no coordinator.  The store applies the
paper's lattice rule (largest ``step`` wins per shard key), so concurrent or
straggling writers can never regress a checkpoint.  Restore + deterministic
data order (seeded, indexable stream) + idempotent metric folds give
exactly-once training-step semantics after any crash (tested in
tests/test_train_loop.py).
"""
from __future__ import annotations

import dataclasses
import pickle
from pathlib import Path
from typing import Any

import jax
import numpy as np


@dataclasses.dataclass
class TrainCheckpoint:
    step: int
    data_idx: int
    params: Any
    opt: Any
    metrics: Any
    rng_seed: int


class LocalStore:
    """Filesystem store; one blob per (worker/partition) key.

    put() keeps the largest-step blob (Algorithm 2 merge rule).
    """

    def __init__(self, root: str | Path):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def _path(self, key: str) -> Path:
        return self.root / f"{key}.ckpt"

    def put(self, key: str, ckpt: TrainCheckpoint) -> bool:
        cur = self.get_step(key)
        if cur is not None and cur > ckpt.step:
            return False
        blob = {
            "step": ckpt.step,
            "data_idx": ckpt.data_idx,
            "rng_seed": ckpt.rng_seed,
            "params": jax.tree.map(np.asarray, ckpt.params),
            "opt": jax.tree.map(np.asarray, ckpt.opt),
            "metrics": jax.tree.map(np.asarray, ckpt.metrics),
        }
        tmp = self._path(key).with_suffix(".tmp")
        with open(tmp, "wb") as f:
            pickle.dump(blob, f)
        tmp.rename(self._path(key))  # atomic publish
        return True

    def get(self, key: str) -> TrainCheckpoint | None:
        p = self._path(key)
        if not p.exists():
            return None
        with open(p, "rb") as f:
            blob = pickle.load(f)
        import jax.numpy as jnp

        to_dev = lambda t: jax.tree.map(jnp.asarray, t)
        return TrainCheckpoint(
            step=blob["step"],
            data_idx=blob["data_idx"],
            rng_seed=blob["rng_seed"],
            params=to_dev(blob["params"]),
            opt=to_dev(blob["opt"]),
            metrics=to_dev(blob["metrics"]),
        )

    def get_step(self, key: str) -> int | None:
        p = self._path(key)
        if not p.exists():
            return None
        with open(p, "rb") as f:
            blob = pickle.load(f)
        return blob["step"]
