"""AdamW with fp32 master moments, built from scratch (no optax dependency).

Moments are stored fp32 and shard exactly like their parameters (the launch
layer assigns a sharding per param leaf and tree-maps it onto the optimizer
state), giving ZeRO-ish partitioned optimizer state for free under GSPMD.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWState:
    step: jax.Array  # i32 scalar
    mu: Any  # fp32, like params
    nu: Any  # fp32, like params


jax.tree_util.register_dataclass(AdamWState, data_fields=["step", "mu", "nu"], meta_fields=[])


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=jax.tree.map(jnp.copy, zeros))


def adamw_update(
    params,
    grads,
    state: AdamWState,
    *,
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
):
    """Returns (new_params, new_state, grad_norm)."""
    # global-norm clip in fp32
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(g32)) + 1e-20
    )
    scale = jnp.minimum(1.0, grad_clip / gnorm)
    g32 = jax.tree.map(lambda g: g * scale, g32)

    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, g32, state.mu, state.nu)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), gnorm
