"""WCRDT-backed training metrics — the paper's technique as a first-class
framework feature (DESIGN.md §4).

Training is an infinite stream of steps partitioned over data-parallel
workers.  Global metric aggregation (mean loss, token throughput, max grad
norm) is a *global aggregation over that stream* — precisely the paper's
problem.  Instead of a blocking all-reduce on the critical path or a
centralized metrics server, every worker owns a replica of a windowed metric
lattice:

  * window      = ``window_len`` consecutive steps ("timestamp" = step id),
  * loss_sum /
    token_count = windowed grow-only counters (per-worker slots, max-merged),
  * grad_norm   = windowed max-register,
  * progress    = per-worker step watermark.

Replicas merge in the background (host gossip thread, or one lattice
all-reduce per sync period on the pod).  A metric window is readable exactly
when the global watermark (min worker step) passes it — at which point every
worker reads the *same, final* value: deterministic dashboards, deterministic
early-stopping decisions, no barrier, straggler-tolerant.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import wcrdt as W
from repro.core.wcrdt import WSpec, WState


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    num_workers: int
    window_len: int = 10  # steps per metric window
    num_slots: int = 8

    def specs(self) -> dict[str, WSpec]:
        return {
            "loss_sum": W.wgcounter(self.window_len, self.num_slots, self.num_workers),
            "tokens": W.wgcounter(self.window_len, self.num_slots, self.num_workers),
            "gnorm_max": W.wmaxreg(self.window_len, self.num_slots, self.num_workers),
        }


def metrics_init(spec: MetricSpec) -> dict[str, WState]:
    return {k: s.zero() for k, s in spec.specs().items()}


def metrics_fold(
    spec: MetricSpec,
    state: dict[str, WState],
    worker,
    step,
    loss: jax.Array,
    n_tokens: jax.Array,
    grad_norm: jax.Array,
) -> dict[str, WState]:
    """Fold one step's local metrics; advance this worker's watermark."""
    specs = spec.specs()
    ts = jnp.asarray(step, jnp.int32)[None]
    one = jnp.ones((1,), jnp.bool_)
    out = dict(state)
    out["loss_sum"] = W.insert(
        specs["loss_sum"], state["loss_sum"], worker, ts, one,
        actor=worker, amounts=jnp.reshape(loss, (1,)).astype(jnp.float32),
    )
    out["tokens"] = W.insert(
        specs["tokens"], state["tokens"], worker, ts, one,
        actor=worker, amounts=jnp.reshape(n_tokens, (1,)).astype(jnp.float32),
    )
    out["gnorm_max"] = W.insert(
        specs["gnorm_max"], state["gnorm_max"], worker, ts, one,
        vals=jnp.reshape(grad_norm, (1,)).astype(jnp.float32),
    )
    # watermark: this worker will never again contribute to steps <= step
    nxt = jnp.asarray(step, jnp.int32) + 1
    for k in out:
        out[k] = W.increment_watermark(specs[k], out[k], worker, nxt)
    return out


def metrics_merge(spec: MetricSpec, a: dict[str, WState], b: dict[str, WState]):
    specs = spec.specs()
    return {k: W.merge(specs[k], a[k], b[k]) for k in a}


def metrics_axis_join(spec: MetricSpec, state: dict[str, WState], axis_name: str):
    """On-pod variant: one lattice all-reduce merges every worker's replica.
    Step-windows are lockstep across workers, so the aligned fast path rides
    pure pmax/pmin (no gather buffer)."""
    specs = spec.specs()
    return {k: W.axis_join_aligned(specs[k], state[k], axis_name) for k in state}


def metrics_read(spec: MetricSpec, state: dict[str, WState], window: int):
    """Read a completed metric window: (dict, ok).  Deterministic across
    workers once ok=True."""
    specs = spec.specs()
    loss_sum, ok1 = W.window_value(specs["loss_sum"], state["loss_sum"], window)
    tokens, ok2 = W.window_value(specs["tokens"], state["tokens"], window)
    gmax, ok3 = W.window_value(specs["gnorm_max"], state["gnorm_max"], window)
    steps = spec.window_len * spec.num_workers
    out = {
        "mean_loss": loss_sum / jnp.maximum(steps, 1),
        "tokens": tokens,
        "grad_norm_max": gmax,
    }
    return out, ok1 & ok2 & ok3
