from repro.training.optimizer import AdamWState, adamw_init, adamw_update
from repro.training.train_step import make_train_step, make_serve_step
from repro.training.metrics import MetricSpec, metrics_init, metrics_fold, metrics_read

__all__ = [
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "make_train_step",
    "make_serve_step",
    "MetricSpec",
    "metrics_init",
    "metrics_fold",
    "metrics_read",
]
