"""Step factories: the jit-able train / prefill / decode steps the launcher
and the dry-run lower.

``make_train_step`` closes over the ArchConfig and optimizer hyperparams and
returns ``step(params, opt, batch) -> (params, opt, stats)`` — forward loss
(remat'd scan), backward, global-norm clip, AdamW.  ``stats`` carries the
scalars the WCRDT metric lattice folds (loss, tokens, grad-norm).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.training.optimizer import AdamWState, adamw_update


def make_train_step(
    cfg: ArchConfig,
    *,
    lr: float = 3e-4,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
    q_chunk: int = 512,
    ssm_chunk: int = 256,
    remat: bool = True,
    grad_accum: int = 1,
) -> Callable:
    def loss_fn(params, batch):
        return lm.forward_loss(
            cfg, params, batch, q_chunk=q_chunk, ssm_chunk=ssm_chunk, remat=remat
        )

    def one_grad(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def step(params, opt: AdamWState, batch: dict):
        if grad_accum > 1:
            # microbatch over the leading batch axis
            def split(x):
                B = x.shape[0]
                return x.reshape(grad_accum, B // grad_accum, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                acc_loss, acc_grads = carry
                l, g = one_grad(params, mb)
                return (
                    acc_loss + l,
                    jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc_grads, g),
                ), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (tot_loss, grads), _ = jax.lax.scan(body, (jnp.float32(0), zero_g), micro)
            loss = tot_loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        else:
            loss, grads = one_grad(params, batch)

        new_params, new_opt, gnorm = adamw_update(
            params, grads, opt, lr=lr, weight_decay=weight_decay, grad_clip=grad_clip
        )
        n_tokens = jnp.asarray(batch["tokens"].size, jnp.float32)
        stats = {"loss": loss, "tokens": n_tokens, "grad_norm": gnorm}
        return new_params, new_opt, stats

    return step


def make_serve_step(cfg: ArchConfig) -> Callable:
    """decode: (params, cache, token, position[, enc_kv]) -> (logits, cache)."""

    def step(params, cache, token, position, enc_kv=None):
        return lm.decode_step(cfg, params, cache, token, position, enc_kv=enc_kv)

    return step


def make_prefill_step(cfg: ArchConfig, *, q_chunk: int = 512, ssm_chunk: int = 256):
    if cfg.is_enc_dec:

        def step(params, enc_embeds, tokens):
            return lm.prefill_encdec(cfg, params, enc_embeds, tokens, q_chunk=q_chunk)

    else:

        def step(params, tokens, prefix_embeds=None):
            return lm.prefill(
                cfg, params, tokens, prefix_embeds=prefix_embeds,
                q_chunk=q_chunk, ssm_chunk=ssm_chunk,
            )

    return step
