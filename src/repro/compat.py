"""Version-compat shims for the jax APIs this repo uses.

The codebase targets current jax (``jax.shard_map``, ``jax.lax.pvary``,
``jax.make_mesh(..., axis_types=...)``); older runtimes (<= 0.4.x) spell
these differently or lack them.  Everything funnels through here so call
sites stay on the modern spelling.
"""
from __future__ import annotations

import jax

_HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")


def shard_map(f, mesh, in_specs, out_specs):
    if _HAS_NATIVE_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _sm

    # old shard_map has no pvary to mark varying outputs; disable rep checks
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)


def pvary(x, axis_names):
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis_names)
    return x  # pre-vma jax: no device-varying tracking, nothing to mark


def make_mesh(shape, axes):
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)
