"""Parameter / input / cache sharding assignment (GSPMD partition specs).

Strategy (DESIGN.md §6): megatron tensor-parallel over ``model`` + ZeRO-3
FSDP over ``data`` for every weight matrix; experts over ``model`` (EP);
batch over (``pod``, ``data``); KV caches over kv-heads (or head_dim when
kv-heads don't divide the axis).  Axes that don't divide a dim are dropped
to replication, so every (arch × shape × mesh) cell lowers cleanly.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

BATCH_AXES = ("pod", "data")

# final-path-name -> spec for the TRAILING dims (leading dims -> None)
_TRAILING: dict[str, tuple] = {
    # vocab-parallel: V over model, D replicated -> logits [B,S,V/model] stay
    # sharded and only the [B,S] logsumexp reduces over model.  (Sharding D
    # over data instead forces an all-reduce of FULL logits — measured 390 GB
    # per device per step in the first dry-run; see EXPERIMENTS.md §Perf.)
    "embed": ("model", None),
    "unembed": ("model", None),
    "wq": ("data", "model"),
    "wk": ("data", "model"),
    "wv": ("data", "model"),
    "wo": ("model", "data"),
    "w_gate": ("data", "model"),
    "w_up": ("data", "model"),
    "w_down": ("model", "data"),
    "router": ("data", None),
    "in_proj": ("data", "model"),
    "out_proj": ("model", "data"),
    "conv_w": (None, "model"),
    "x_proj": ("model", None),
    "dt_proj": (None, "model"),
    "dt_bias": ("model",),
    "A_log": ("model", None),
    "D": ("model",),
    "gate_norm": ("model",),
    "bc_proj": ("data", None),
}

# expert-stacked weights (path contains "moe"): E over model, D over data
_TRAILING_MOE: dict[str, tuple] = {
    "w_gate": ("model", "data", None),
    "w_up": ("model", "data", None),
    "w_down": ("model", None, "data"),
    "router": ("data", None),
}


def _axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _fit(spec: tuple, shape: tuple[int, ...], sizes: dict[str, int]) -> P:
    """Right-align the trailing spec onto shape; drop non-dividing axes."""
    full = (None,) * (len(shape) - len(spec)) + tuple(spec)
    out = []
    for dim, ax in zip(shape, full):
        if ax is None or ax not in sizes or dim % sizes[ax] != 0:
            out.append(None)
        else:
            out.append(ax)
    return P(*out)


def param_pspec(path, leaf, sizes: dict[str, int], cfg=None) -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    last = names[-1]
    table = _TRAILING_MOE if ("moe" in names and last in _TRAILING_MOE) else _TRAILING
    if last in table:
        spec = table[last]
        # rank-aware fixups
        if last == "A_log" and leaf.ndim - (1 if names[0] in ("layers", "tail", "enc_layers") else 0) == 1:
            spec = ("model",)
        if last == "D":
            spec = ("model",)
        # Attention TP only when the head count divides the model axis;
        # otherwise attention runs data-parallel (weights FSDP-sharded only).
        # GSPMD's padded fallback for uneven head shards was measured to
        # all-gather full-batch activations (EXPERIMENTS.md §Perf iter 1).
        if cfg is not None and "model" in sizes:
            tp = sizes["model"]
            if last in ("wq", "wo") and cfg.n_heads % tp != 0:
                spec = tuple(None if a == "model" else a for a in spec)
            if last in ("wk", "wv") and cfg.n_kv_heads % tp != 0:
                spec = tuple(None if a == "model" else a for a in spec)
        return _fit(spec, leaf.shape, sizes)
    # norms and anything unknown: replicate
    return P(*([None] * leaf.ndim))


def shard_params(abs_params, mesh, cfg=None, strategy: str = "megatron") -> Any:
    sizes = _axis_sizes(mesh)
    if strategy == "zero3":
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: NamedSharding(mesh, _zero3_pspec(leaf, sizes)),
            abs_params,
        )
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_pspec(path, leaf, sizes, cfg)),
        abs_params,
    )


def _zero3_pspec(leaf, sizes: dict[str, int]) -> P:
    """ZeRO-3 / pure-DP: every weight fully sharded over (data x model) on its
    largest evenly-dividing dim; gathered at use, reduce-scattered in bwd.
    No tensor parallelism — the whole mesh acts as one DP domain."""
    ways = sizes.get("data", 1) * sizes.get("model", 1)
    shape = leaf.shape
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if shape[i] % ways == 0:
            spec = [None] * len(shape)
            spec[i] = ("data", "model")
            return P(*spec)
    for i in order:  # fall back to data-only sharding
        if shape[i] % sizes.get("data", 1) == 0:
            spec = [None] * len(shape)
            spec[i] = "data"
            return P(*spec)
    return P(*([None] * len(shape)))


def batch_pspec(B: int, extra_dims: int, mesh) -> P:
    from repro.models import flags

    sizes = _axis_sizes(mesh)
    axes = tuple(a for a in flags.batch_axes() if a in sizes)
    n = 1
    for a in axes:
        n *= sizes[a]
    if axes and B % n == 0:
        return P(axes, *([None] * extra_dims))
    return P(*([None] * (1 + extra_dims)))


def shard_inputs(abs_batch, mesh) -> Any:
    def one(leaf):
        return NamedSharding(mesh, batch_pspec(leaf.shape[0], leaf.ndim - 1, mesh))

    return jax.tree.map(one, abs_batch)


def cache_pspec(path, leaf, mesh, ssm_version: int = 1) -> P:
    """KV / SSM cache sharding: batch over (pod,data); heads (or head_dim)
    over model.  Cache leaves carry 1-2 leading stack dims from the layer
    scan; specs are right-aligned so the rank of the stack prefix is
    irrelevant."""
    sizes = _axis_sizes(mesh)
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    last = names[-1]
    shape = leaf.shape

    if last in ("k", "v"):
        # [..., B, T, K, hd]
        bspec = batch_pspec(shape[-4], 0, mesh)
        b_ax = bspec[0] if len(bspec) else None
        kax = "model" if shape[-2] % sizes.get("model", 1) == 0 else None
        hax = "model" if kax is None and shape[-1] % sizes.get("model", 1) == 0 else None
        return _fit((b_ax, None, kax, hax), shape, sizes)
    if last in ("ssm", "tail_ssm"):
        # mamba1 [..., B, Di, N]: shard Di; mamba2 [..., B, H, P, N]: shard H
        trailing = 3 if ssm_version == 1 else 4
        bdim = shape[-trailing]
        bspec = batch_pspec(bdim, 0, mesh)
        b_ax = bspec[0] if len(bspec) else None
        spec = (b_ax, "model") + (None,) * (trailing - 2)
        return _fit(spec, shape, sizes)
    if last in ("conv", "tail_conv"):
        # [..., B, K-1, Di]
        bspec = batch_pspec(shape[-3], 0, mesh)
        return _fit((bspec[0] if len(bspec) else None, None, "model"), shape, sizes)
    return P(*([None] * leaf.ndim))


def shard_cache(abs_cache, mesh, ssm_version: int = 1) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, cache_pspec(path, leaf, mesh, ssm_version)
        ),
        abs_cache,
    )


def replicated(mesh):
    return NamedSharding(mesh, P())
