"""End-to-end training driver.

Trains a decoder LM on a synthetic-but-deterministic token stream with the
paper's runtime features live:

  * WCRDT training-metric windows across ``--metric-workers`` virtual metric
    partitions (step-windowed lattices; a window prints exactly when the
    global watermark passes it — deterministic regardless of fold order),
  * decentralized "sometimes" checkpoints (LocalStore, largest-step merge),
  * optional mid-run crash/restore (--crash-at) demonstrating bit-exact
    continuation (exactly-once steps, deterministic replay).

Usage:
  PYTHONPATH=src python -m repro.launch.train --steps 50 --preset tiny
  PYTHONPATH=src python -m repro.launch.train --steps 300 --preset 100m
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.training import adamw_init
from repro.training.checkpoint import LocalStore, TrainCheckpoint
from repro.training.metrics import (
    MetricSpec,
    metrics_fold,
    metrics_init,
    metrics_read,
)
from repro.training.train_step import make_train_step
from repro.models import init_params

PRESETS = {
    "tiny": ArchConfig(
        name="tiny-lm", family="dense", n_layers=4, d_model=256, n_heads=4,
        n_kv_heads=4, d_ff=1024, vocab=4096,
    ),
    "100m": ArchConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=12, d_ff=3072, vocab=32_000,
    ),
}


def synthetic_batch(seed: int, idx: int, B: int, S: int, vocab: int):
    """Deterministic, indexable token stream (the replayable input log)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), idx)
    return {"tokens": jax.random.randint(key, (B, S), 0, vocab)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--metric-workers", type=int, default=4)
    ap.add_argument("--metric-window", type=int, default=5)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--crash-at", type=int, default=-1,
                    help="simulate a crash+restore after this step")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = PRESETS[args.preset]
    mspec = MetricSpec(num_workers=args.metric_workers, window_len=args.metric_window)
    store = LocalStore(args.ckpt_dir)
    step_fn = jax.jit(make_train_step(cfg, lr=args.lr, q_chunk=128, ssm_chunk=64))

    def fresh_state():
        params = init_params(cfg, jax.random.PRNGKey(args.seed), dtype=jnp.float32)
        return params, adamw_init(params), metrics_init(mspec), 0

    # resume from the freshest checkpoint if one exists
    ck = store.get("worker0")
    if ck is not None:
        print(f"[resume] restoring step {ck.step} from {args.ckpt_dir}")
        params, opt, metrics, start = ck.params, ck.opt, ck.metrics, ck.step
    else:
        params, opt, metrics, start = fresh_state()
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model={cfg.name} params={n_params/1e6:.1f}M workers={args.metric_workers}")

    emitted = start // args.metric_window
    t0 = time.time()
    step = start
    while step < args.steps:
        batch = synthetic_batch(args.seed, step, args.batch, args.seq, cfg.vocab)
        params, opt, stats = step_fn(params, opt, batch)
        # fold local stats into this step's metric partition (round-robin
        # stand-in for real DP workers; fold order cannot change any window)
        worker = step % args.metric_workers
        metrics = metrics_fold(
            mspec, metrics, worker, step // args.metric_workers,
            stats["loss"], stats["tokens"], stats["grad_norm"],
        )
        step += 1

        # print every metric window the global watermark has passed
        while True:
            vals, ok = metrics_read(mspec, metrics, emitted)
            if not bool(ok):
                break
            dt = time.time() - t0
            print(
                f"[window {emitted:4d}] steps<{(emitted+1)*args.metric_window*args.metric_workers} "
                f"mean_loss={float(vals['mean_loss']):.4f} "
                f"tokens={float(vals['tokens']):.0f} "
                f"gnorm_max={float(vals['grad_norm_max']):.3f} "
                f"({dt:.1f}s)"
            )
            emitted += 1

        if step % args.ckpt_every == 0:
            store.put(
                "worker0",
                TrainCheckpoint(
                    step=step, data_idx=step, params=params, opt=opt,
                    metrics=metrics, rng_seed=args.seed,
                ),
            )
        if step == args.crash_at:
            print(f"[crash] simulated failure at step {step}; recovering...")
            ck = store.get("worker0")
            if ck is None:
                params, opt, metrics, step = fresh_state()
            else:
                params, opt, metrics, step = ck.params, ck.opt, ck.metrics, ck.step
            args.crash_at = -1  # crash once

    final = stats
    print(
        f"done: {args.steps} steps in {time.time()-t0:.1f}s "
        f"final_loss={float(final['loss']):.4f}"
    )
    return float(final["loss"])


if __name__ == "__main__":
    main()
