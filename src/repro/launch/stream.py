"""Production streaming driver: the whole Holon pipeline as ONE shard_map
program over the ``data`` mesh axis — partition-per-device, batched folds,
background sync as a lattice collective, windows emitted from the device.

This is the TPU-native deployment path (DESIGN.md §3): the discrete-event
harness in repro/runtime measures coordination behaviour; this driver is the
dataplane that would actually run on a pod, and what bench_throughput
measures for raw events/s.

Usage:
  PYTHONPATH=src python -m repro.launch.stream --query q7 --batches 64
  (optionally XLA_FLAGS=--xla_force_host_platform_device_count=8 for a
   multi-device run on CPU)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import wcrdt as W
from repro.streaming.events import EventBatch
from repro.streaming.generator import NexmarkConfig, generate_log
from repro.streaming.queries import Query, make_q1_ratio, make_q4, make_q7

MAKERS = {"q4": make_q4, "q7": make_q7, "q1_ratio": make_q1_ratio}


def build_pipeline(query: Query, mesh, sync_every: int):
    """Returns a jitted fn: (log slice per device) -> per-window outputs.

    Scans batches; every ``sync_every`` folds runs one lattice all-reduce
    (the background sync); finally reads every completed window.
    """

    n_windows = 64

    def node_fn(log: EventBatch):
        p = jax.lax.axis_index("data")
        # mark replica state device-varying from the start (shard_map vma)
        vary = lambda t: jax.tree.map(lambda x: jax.lax.pvary(x, ("data",)), t)
        shared = vary(query.init_shared())
        local = vary(query.init_local())

        def fold_one(carry, batch):
            shared, local = carry
            shared, local = query.fold(shared, local, batch, p)
            return (shared, local), None

        def sync_chunk(carry, chunk):
            # sync_every folds, then one background-sync collective
            carry, _ = jax.lax.scan(fold_one, carry, chunk)
            shared, local = carry
            shared = tuple(
                W.axis_join(spec, st, "data")
                for spec, st in zip(query.shared_specs, shared)
            )
            return (shared, local), None

        log0 = jax.tree.map(lambda x: x[0], log)  # strip device-local lead dim
        nb = jax.tree.leaves(log0)[0].shape[0]
        n_outer = nb // sync_every
        chunked = jax.tree.map(
            lambda x: x[: n_outer * sync_every].reshape(
                n_outer, sync_every, *x.shape[1:]
            ),
            log0,
        )
        (shared, local), _ = jax.lax.scan(sync_chunk, (shared, local), chunked)

        def read(w):
            v, ok = query.read(shared, local, w)
            return jnp.where(ok, 1.0, 0.0), v

        oks, vals = jax.vmap(read)(jnp.arange(n_windows))
        return oks[None], vals[None]

    log_specs = jax.tree.map(lambda _: P("data"), EventBatch(*([0] * 7)))
    return jax.jit(
        jax.shard_map(
            node_fn,
            mesh=mesh,
            in_specs=(log_specs,),
            out_specs=(P("data"), P("data")),
        )
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--query", default="q7", choices=list(MAKERS))
    ap.add_argument("--batches", type=int, default=64)
    ap.add_argument("--events-per-batch", type=int, default=1024)
    ap.add_argument("--window-len", type=int, default=1000)
    ap.add_argument("--sync-every", type=int, default=4)
    args = ap.parse_args(argv)

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    nx = NexmarkConfig(
        num_partitions=n_dev,
        num_batches=args.batches,
        events_per_batch=args.events_per_batch,
    )
    log = generate_log(nx)
    query = MAKERS[args.query](n_dev, window_len=args.window_len, num_slots=64)

    with mesh:
        pipe = build_pipeline(query, mesh, args.sync_every)
        oks, vals = pipe(log)  # compile+run
        jax.block_until_ready(oks)
        t0 = time.time()
        oks, vals = pipe(log)
        jax.block_until_ready(oks)
        dt = time.time() - t0

    total_events = n_dev * args.batches * args.events_per_batch
    done = int(np.asarray(oks).sum()) // n_dev
    print(
        f"devices={n_dev} events={total_events} wall={dt*1e3:.1f}ms "
        f"throughput={total_events/dt/1e6:.2f}M ev/s complete_windows={done}"
    )
    return total_events / dt


if __name__ == "__main__":
    main()
