"""Production streaming driver: the whole Holon pipeline as ONE shard_map
program over the ``data`` mesh axis — partition-per-device, batched folds,
background sync as a lattice collective, windows emitted from the device.

This is the TPU-native deployment path (DESIGN.md §3): the discrete-event
harness in repro/runtime measures coordination behaviour; this driver is the
dataplane that would actually run on a pod, and what bench_throughput
measures for raw events/s.

Background sync is delta-state by default (DESIGN.md §6): each device carries
the shared post-last-sync baseline ``(folded, progress)``, extracts only the
ring slots its folds dirtied since then (``W.delta_since``), and the deltas
are exchanged and joined by the dirty-slot-gated merge kernel — slots with
``slot_wid < 0`` are skipped, not reduced.  The per-round shipped bytes
(``W.delta_nbytes``, what a real gossip transport would put on the wire
instead of the whole ring) come back as a pipeline output so the win is
measured, not asserted.  ``--full-sync`` restores the full-state lattice
all-reduce for comparison.

Usage:
  PYTHONPATH=src python -m repro.launch.stream --query q7 --batches 64
  (optionally XLA_FLAGS=--xla_force_host_platform_device_count=8 for a
   multi-device run on CPU)
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import wcrdt as W
from repro.core.window import as_assigner
from repro.obs.timing import WallTimer
from repro.streaming.events import KIND_BID, EventBatch
from repro.streaming.generator import NexmarkConfig, batch_watermark, generate_log
from repro.streaming.queries import (
    Query,
    make_q0,
    make_q1_ratio,
    make_q4,
    make_q5,
    make_q7,
)

# every query the benchmarks import is runnable on the dataplane, including
# the shared-state-free q0 (sync rounds no-op) and the sliding-window q5
MAKERS = {
    "q0": make_q0,
    "q1_ratio": make_q1_ratio,
    "q4": make_q4,
    "q5": make_q5,
    "q7": make_q7,
}


def build_pipeline(
    query: Query, mesh, sync_every: int, delta_sync: bool = True,
    n_windows: int = 64, first_window: int = 0,
):
    """Returns a jitted fn: (log slice per device) -> (oks, vals, sync_bytes).

    Scans batches; every ``sync_every`` folds runs one background-sync
    exchange (delta-state by default, full-state all-reduce with
    ``delta_sync=False``); finally reads window ids ``first_window ..
    first_window + n_windows`` (overlapping assigners close a window every
    ``hop``, not every ``window_len``, and long runs evict the oldest ids
    from the ring — size and offset via ``read_window_range``).  A query
    with no shared state (q0) simply skips the exchange: the per-spec loop
    is empty and ``sync_bytes`` stays 0.  ``sync_bytes`` is each device's
    total modeled sync traffic in bytes.
    """

    def node_fn(log: EventBatch):
        p = jax.lax.axis_index("data")
        # mark replica state device-varying from the start (shard_map vma)
        vary = lambda t: jax.tree.map(lambda x: compat.pvary(x, ("data",)), t)
        shared = vary(query.init_shared())
        local = vary(query.init_local())
        baselines = tuple(W.baseline_of(st) for st in shared)
        sync_bytes = compat.pvary(jnp.float32(0.0), ("data",))

        def fold_one(carry, batch):
            # batch_idx advances the folded frontier — what delta_since diffs
            shared, local, idx = carry
            shared, local = query.fold(shared, local, batch, p, batch_idx=idx)
            return (shared, local, idx + 1), None

        def sync_chunk(carry, chunk):
            # sync_every folds, then one background-sync exchange
            shared, local, idx, baselines, sync_bytes = carry
            (shared, local, idx), _ = jax.lax.scan(
                fold_one, (shared, local, idx), chunk
            )
            synced, new_base = [], []
            for spec, st, (bf, bp) in zip(query.shared_specs, shared, baselines):
                if delta_sync:
                    st, shipped = W.delta_axis_join(spec, st, bf, bp, "data")
                else:
                    st = W.axis_join(spec, st, "data")
                    shipped = jnp.float32(W.state_nbytes(st))
                sync_bytes = sync_bytes + shipped
                synced.append(st)
                new_base.append(W.baseline_of(st))
            return (tuple(synced), local, idx, tuple(new_base), sync_bytes), None

        log0 = jax.tree.map(lambda x: x[0], log)  # strip device-local lead dim
        nb = jax.tree.leaves(log0)[0].shape[0]
        n_outer = nb // sync_every
        chunked = jax.tree.map(
            lambda x: x[: n_outer * sync_every].reshape(
                n_outer, sync_every, *x.shape[1:]
            ),
            log0,
        )
        idx0 = compat.pvary(jnp.int32(0), ("data",))
        (shared, local, _, _, sync_bytes), _ = jax.lax.scan(
            sync_chunk, (shared, local, idx0, baselines, sync_bytes), chunked
        )

        def read(w):
            v, ok = query.read(shared, local, w)
            return jnp.where(ok, 1.0, 0.0), v

        oks, vals = jax.vmap(read)(first_window + jnp.arange(n_windows))
        return oks[None], vals[None], sync_bytes[None]

    log_specs = jax.tree.map(lambda _: P("data"), EventBatch(*([0] * 7)))
    return jax.jit(
        compat.shard_map(
            node_fn,
            mesh=mesh,
            in_specs=(log_specs,),
            out_specs=(P("data"), P("data"), P("data")),
        )
    )


def default_fold_schedule(num_shards: int, num_batches: int) -> np.ndarray:
    """Failure-free fold schedule for :func:`build_keyed_pipeline`: i32
    ``[num_shards, num_batches]`` — every device folds batch ``t`` at step
    ``t``.  Crash-recovery tests splice a replay (``[0..k, j..k, k+1..]``)
    into a device's row; the ``folded`` frontier makes re-folds no-ops, so
    the splice reproduces deterministic replay recovery byte-for-byte
    (docs/protocol.md §6)."""
    return np.tile(np.arange(num_batches, dtype=np.int32), (num_shards, 1))


def build_keyed_pipeline(
    mesh, shards: W.KeyShards, *, window_len: int = 1000,
    num_slots: int = 16, hop: int | None = None, sync_every: int = 4,
    n_windows: int = 8, first_window: int = 0, provenance: bool = False,
):
    """Hash-sharded keyed dataplane (docs/protocol.md §6): per-auction bid
    counts + cross-shard hot-item reads over a key domain too large for any
    single device's dense ``[W, C]`` state.

    Jitted signature: ``(log, key_table, sched, wm_sync) -> (oks, vals,
    shuffle_bytes, sync_bytes)`` where

    * ``log`` — EventBatch ``[S, num_batches, B]``, sharded over ``data``;
    * ``key_table`` — ``shards.key_table()``, sharded over ``data`` (each
      device keeps only its own inverse row);
    * ``sched`` — replicated i32 ``[S, n_steps]`` fold schedule
      (:func:`default_fold_schedule`); column ``t`` names the batch index
      each device folds at step ``t``, so every device can label the lanes
      it RECEIVES with the sender's ``batch_idx`` without shipping it;
    * ``wm_sync`` — replicated bool ``[n_steps // sync_every]``; round
      ``r``'s watermark exchange runs only where True (False = partitioned:
      progress maps diverge and windows stall until heal).

    Unlike :func:`build_pipeline` (replicate-everywhere + lattice join),
    keys are ROUTED: device ``s`` owns key range ``{k : shards.shard_of(k)
    == s}``, each step all-to-alls the masked ``[S, B]`` routing matrix so
    every owner folds exactly the lanes it owns, and each device's state is
    ``[W, ceil(C/S)]`` — per-device state bytes scale ~1/S.  Ownership is
    exclusive, so the sync plane ships ONLY the ``[S]`` progress map (no
    slot deltas to reconcile); both modeled byte counters come back as
    outputs.  Final read: :func:`W.shard_topk_read` per window — one
    ``[S]``-candidate gather, never the full key range.

    With ``provenance=True`` the jitted fn returns a fifth output: each
    device's i32 ``[S]`` **ingest frontier** — the max event timestamp among
    the keyed lanes it folded from each source device (``-2^31`` where a
    source never routed it a bid).  This is the dataplane analog of the sync
    plane's progress lattice: the host can tell which *source's* routed
    lanes gate an owner's window close, the per-lane provenance the
    critical-path analyzer reconstructs for the coordination harness
    (docs/observability.md §5).  Default stays the 4-output signature with
    zero added work.
    """
    S = shards.num_shards
    assigner = as_assigner(window_len, hop if hop else window_len // 2)
    spec = W.wgcounter_sharded(window_len, num_slots, S, shards, assigner=assigner)
    wm_bytes = jnp.float32(S * 4)  # the [S] i32 progress map, per round

    def node_fn(log: EventBatch, key_table, sched, wm_sync):
        me = jax.lax.axis_index("data")
        vary = lambda t: jax.tree.map(lambda x: compat.pvary(x, ("data",)), t)
        state = vary(spec.zero())
        log0 = jax.tree.map(lambda x: x[0], log)  # [num_batches, B] leaves
        table0 = compat.pvary(key_table[0], ("data",))  # u32 [width]
        B = log0.ts.shape[1]
        rows = jnp.arange(S, dtype=jnp.int32)[:, None]  # [S, 1]
        a2a = lambda x: jax.lax.all_to_all(
            x, "data", split_axis=0, concat_axis=0, tiled=True
        )

        def fold_step(carry, sched_col):
            state, shuffle_bytes, prov = carry
            batch = jax.tree.map(lambda x: x[sched_col[me]], log0)
            is_bid = batch.valid & (batch.kind == KIND_BID)
            owner = shards.shard_of(batch.auction)
            local = shards.local_of(batch.auction)
            # routing matrix: row s = my lanes owned by device s
            m_sb = is_bid[None, :] & (owner[None, :] == rows)  # [S, B]
            r_ts = a2a(jnp.broadcast_to(batch.ts[None, :], (S, B)))
            r_loc = a2a(jnp.broadcast_to(local[None, :], (S, B)))
            r_mask = a2a(m_sb)
            # wire model: off-device lanes ship (ts, local) = 8 bytes each
            sent = m_sb & (rows != me)
            shuffle_bytes = shuffle_bytes + jnp.sum(sent) * jnp.float32(8.0)
            # after the exchange, row r holds lanes from source device r,
            # folded at r's scheduled batch index (sched is replicated)
            src = jnp.broadcast_to(rows, (S, B)).reshape(-1)
            bi = jnp.broadcast_to(sched_col[:, None], (S, B)).reshape(-1)
            state = W.insert(
                spec, state, src, r_ts.reshape(-1), r_mask.reshape(-1),
                batch_idx=bi, amounts=jnp.ones((S * B,), jnp.float32),
                keys=r_loc.reshape(-1),
            )
            if provenance:
                # ingest frontier: max event ts among the lanes row r (source
                # device r) routed to me this step — flag-static, so the
                # default build traces no extra ops
                lane_ts = jnp.where(r_mask, r_ts, jnp.int32(-(2**31)))
                prov = jnp.maximum(prov, lane_ts.max(axis=1))
            state = W.increment_watermark(spec, state, me, batch_watermark(batch))
            return (state, shuffle_bytes, prov), None

        def sync_round(carry, round_in):
            chunk, wm_on = round_in
            state, shuffle_bytes, sync_bytes, prov = carry
            (state, shuffle_bytes, prov), _ = jax.lax.scan(
                fold_step, (state, shuffle_bytes, prov), chunk
            )
            merged = jnp.where(wm_on, jax.lax.pmax(state.progress, "data"),
                               state.progress)
            state = dataclasses.replace(state, progress=merged)
            sync_bytes = sync_bytes + jnp.where(wm_on, wm_bytes, 0.0)
            return (state, shuffle_bytes, sync_bytes, prov), None

        n_steps = sched.shape[1]
        n_rounds = n_steps // sync_every
        chunks = (
            sched.T[: n_rounds * sync_every]
            .reshape(n_rounds, sync_every, S)
            .astype(jnp.int32)
        )
        zero = compat.pvary(jnp.float32(0.0), ("data",))
        prov0 = compat.pvary(
            jnp.full((S,), -(2**31), jnp.int32), ("data",)
        )
        (state, shuffle_bytes, sync_bytes, prov), _ = jax.lax.scan(
            sync_round, (state, zero, zero, prov0), (chunks, wm_sync[:n_rounds])
        )

        def read(w):
            (cnt, key), ok = W.shard_topk_read(
                spec, state, w, table0, shards.num_keys, "data", k=1
            )
            val = jnp.stack([cnt[0], key[0].astype(jnp.float32)])
            return jnp.where(ok, 1.0, 0.0), val

        oks, vals = jax.vmap(read)(first_window + jnp.arange(n_windows))
        out = (oks[None], vals[None], shuffle_bytes[None], sync_bytes[None])
        if provenance:
            out += (prov[None],)
        return out

    n_out = 5 if provenance else 4
    log_specs = jax.tree.map(lambda _: P("data"), EventBatch(*([0] * 7)))
    return jax.jit(
        compat.shard_map(
            node_fn,
            mesh=mesh,
            in_specs=(log_specs, P("data"), P(), P()),
            out_specs=tuple(P("data") for _ in range(n_out)),
        )
    )


def read_window_range(query: Query, horizon_ts: float) -> tuple[int, int]:
    """``(first_wid, n_windows)`` worth reading after a ``horizon_ts`` run:
    the LAST ring-residency-capped window ids closing within the horizon —
    on long runs the earliest ids have been evicted from the ring and would
    read not-ok, so the range ends at the horizon rather than starting at 0.

    Residency is anchored at the NEWEST assigned wid, which under overlap
    runs ``K - 1`` ahead of the newest *complete* one — the usable span is
    ``num_slots - (K - 1)`` complete ids plus the one still-open id at the
    top of the range (reads not-ok; kept so the count is horizon-exact).
    """
    a = query.assigner
    closed = int(a.first_dirty_wid(horizon_ts))
    # residency is bounded by the SMALLEST ring a read touches — shared
    # AND local (q1_ratio-style reads consult both)
    rings = [st.num_slots for st in query.shared_specs]
    if query.local_spec is not None:
        rings.append(query.local_spec.num_slots)
    cap = min(rings) if rings else 64
    n = max(1, min(closed + 1, cap - (a.windows_per_event - 1)))
    return max(0, closed + 1 - n), n


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--query", default="q7", choices=sorted(MAKERS))
    ap.add_argument("--batches", type=int, default=64)
    ap.add_argument("--events-per-batch", type=int, default=1024)
    ap.add_argument("--window-len", type=int, default=1000)
    ap.add_argument("--hop", type=int, default=0,
                    help="hopping-window hop; 0 = the query's default "
                         "(tumbling, except q5 which slides by window/2)")
    ap.add_argument("--sync-every", type=int, default=4)
    ap.add_argument("--full-sync", action="store_true",
                    help="full-state lattice all-reduce instead of delta sync")
    args = ap.parse_args(argv)
    if not 1 <= args.sync_every <= args.batches:
        ap.error(f"--sync-every must be in [1, --batches]; got {args.sync_every}")

    n_dev = len(jax.devices())
    mesh = compat.make_mesh((n_dev,), ("data",))
    nx = NexmarkConfig(
        num_partitions=n_dev,
        num_batches=args.batches,
        events_per_batch=args.events_per_batch,
    )
    log = generate_log(nx)
    kw = {"hop": args.hop} if args.hop else {}
    query = MAKERS[args.query](n_dev, window_len=args.window_len, num_slots=64, **kw)
    horizon_ts = args.batches * nx.batch_span_ms
    first_window, n_windows = read_window_range(query, horizon_ts)

    with mesh:
        pipe = build_pipeline(query, mesh, args.sync_every,
                              delta_sync=not args.full_sync,
                              n_windows=n_windows, first_window=first_window)
        oks, vals, sb = pipe(log)  # compile+run
        jax.block_until_ready(oks)
        # wall-clock domain, explicitly: the dataplane is the one place this
        # driver may read the host clock (docs/observability.md §1)
        with WallTimer() as tm:
            oks, vals, sb = pipe(log)
            jax.block_until_ready(oks)
        dt = tm.dt

    total_events = n_dev * args.batches * args.events_per_batch
    done = int(np.asarray(oks).sum()) // n_dev
    rounds = max(args.batches // args.sync_every, 1)
    sync_per_round = float(np.asarray(sb).mean()) / rounds
    a = query.assigner
    print(
        f"devices={n_dev} events={total_events} wall={dt*1e3:.1f}ms "
        f"throughput={total_events/dt/1e6:.2f}M ev/s "
        f"window={a.window_len}/hop={a.hop} complete_windows={done} "
        f"sync={'full' if args.full_sync else 'delta'} "
        f"sync_bytes_per_round={sync_per_round:.0f}"
    )
    return total_events / dt


if __name__ == "__main__":
    main()
