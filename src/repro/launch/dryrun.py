import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture × input-shape) cell
on the production meshes and extract the roofline terms.

For each cell we build abstract (ShapeDtypeStruct) params / optimizer state /
inputs — no host RAM is allocated — assign shardings, `.lower().compile()`
under the mesh, and record:

  * compiled.memory_analysis()  — proves the working set fits per device,
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * a collective-bytes parse of the HLO (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute operand bytes),

written to benchmarks/results/dryrun/<arch>_<cell>_<mesh>.json and summarized
in EXPERIMENTS.md §Dry-run.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-4b --cell train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
"""
import argparse
import json
import pathlib
import re
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, list_archs, runnable_cells
from repro.launch import hlo_analysis
from repro.launch import shardings as sh
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.training.optimizer import AdamWState
from repro.training.train_step import make_prefill_step, make_serve_step, make_train_step

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

# v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
LINK_BW = 50e9  # B/s / link (ICI)

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4, "s8": 1,
    "u8": 1, "pred": 1, "s16": 2, "u16": 2, "s64": 8, "u64": 8, "f8e4m3": 1,
    "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(\w+)\[([\d,]*)\][^=]*?\b"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b"
)


def parse_collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum tensor bytes per collective kind from compiled HLO.

    Ring-model factors convert tensor size to bytes crossing links:
    all-reduce 2x (reduce-scatter + all-gather phases), others 1x.
    """
    totals: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        b = n * _DTYPE_BYTES[dtype]
        factor = 2.0 if kind == "all-reduce" else 1.0
        totals[kind] = totals.get(kind, 0.0) + b * factor
    totals["total"] = sum(v for k, v in totals.items() if k != "total")
    return totals


# --------------------------------------------------------------------------
# abstract inputs per (arch, cell)
# --------------------------------------------------------------------------


def input_specs(arch: str, cell_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    cfg = get_config(arch)
    cell = SHAPES[cell_name]
    B, S = cell.global_batch, cell.seq_len
    f = jnp.bfloat16
    if cell.kind == "train":
        if cfg.is_enc_dec:
            dec = S // 4
            return {
                "tokens": jax.ShapeDtypeStruct((B, dec), jnp.int32),
                "enc_embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), f),
            }
        if cfg.family == "vlm":
            P = cfg.frontend_prefix
            return {
                "tokens": jax.ShapeDtypeStruct((B, S - P), jnp.int32),
                "prefix_embeds": jax.ShapeDtypeStruct((B, P, cfg.d_model), f),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cell.kind == "prefill":
        if cfg.is_enc_dec:
            dec = S // 4
            return {
                "enc_embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), f),
                "tokens": jax.ShapeDtypeStruct((B, dec), jnp.int32),
            }
        if cfg.family == "vlm":
            P = cfg.frontend_prefix
            return {
                "tokens": jax.ShapeDtypeStruct((B, S - P), jnp.int32),
                "prefix_embeds": jax.ShapeDtypeStruct((B, P, cfg.d_model), f),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    # decode: one new token against a seq_len KV cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


def abstract_params(cfg, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda k: lm.init_params(cfg, k, dtype), jax.random.PRNGKey(0))


def abstract_cache(cfg, B: int, T: int, dtype=jnp.bfloat16):
    from repro.models import cache_spec

    return jax.eval_shape(lambda: cache_spec(cfg, B, T, dtype))


def count_params(abs_params) -> int:
    import math

    return sum(math.prod(l.shape) for l in jax.tree.leaves(abs_params))


def model_flops(cfg, cell, n_params: int) -> float:
    """6·N·D for training; 2·N·D for forward-only (prefill/decode)."""
    if cell.kind == "train":
        if cfg.is_enc_dec:
            tokens = cell.global_batch * (cell.seq_len + cell.seq_len // 4)
        else:
            tokens = cell.global_batch * cell.seq_len
        n = active_params(cfg, n_params)
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * active_params(cfg, n_params) * tokens
    return 2.0 * active_params(cfg, n_params) * cell.global_batch  # decode: 1 tok


def active_params(cfg, n_params: int) -> float:
    """MoE: count only top-k of the expert params as active."""
    if cfg.moe_experts:
        # expert share of total params (approximate from dims)
        n_moe_layers = cfg.n_layers // cfg.moe_every
        expert_p = n_moe_layers * cfg.moe_experts * 3 * cfg.d_model * (cfg.moe_d_ff or cfg.d_ff)
        active_expert = expert_p * cfg.moe_top_k / cfg.moe_experts
        return n_params - expert_p + active_expert
    return float(n_params)


def analytic_bytes(cfg, cell, n_params: int, n_chips: int) -> float:
    """Per-chip HBM traffic model (bytes/step) for the roofline memory term.

    Why not HLO bytes: the CPU backend leaves bf16<->f32 converts and copies
    unfused that TPU XLA fuses away, inflating parsed bytes ~5-10x (measured;
    EXPERIMENTS.md §Dry-run).  This model counts the traffic a fused TPU
    execution pays:

      train:   weight shard read x3 (fwd, remat-recompute, bwd) + optimizer
               read/write (bf16 param + 2 fp32 moments + fp32 grad r/w)
               + residual-stream activations (~16 r/w passes per layer with
               remat) + attention score blocks (2 passes, fp32)
               + logits chunks (fwd+bwd)
      prefill: weight shard read x1 + activations x4 + scores x1 + KV write
      decode:  weight shard read x1 + KV cache read+write + activations
    """
    cell_kind = cell.kind
    B, S = cell.global_batch, cell.seq_len
    D, L = cfg.d_model, cfg.n_layers
    Hp = ((cfg.n_heads + 15) // 16) * 16
    hd = cfg.head_dim
    V = cfg.vocab
    p_shard = n_params / n_chips
    dp = min(B, 32 if n_chips == 512 else 16)  # batch ways (pod x data)
    tp = 16
    b_loc = max(1, B // dp)
    h_loc = max(1, Hp // tp)

    act_tok = b_loc * S * D * 2  # one residual tensor, bf16
    if cell_kind == "train":
        w = p_shard * 2 * 3 + p_shard * (2 * 2 + 8 * 2 + 4 * 2)  # fwd/remat/bwd + opt
        acts = 16 * L * act_tok
        if cfg.family in ("ssm", "hybrid"):
            scores = 0.0
            n_ssm = L
            acts += 10 * n_ssm * b_loc * S * cfg.d_inner * 2 / tp * min(tp, 16)
        else:
            n_attn = L if cfg.family != "hybrid" else L // max(1, cfg.hybrid_attn_every)
            scores = 2 * n_attn * b_loc * h_loc * S * S * 4
        logits = 2 * 2 * b_loc * S * (V / tp) * 2
        return w + acts + scores + logits
    if cell_kind == "prefill":
        w = p_shard * 2
        acts = 6 * L * act_tok
        if cfg.family in ("ssm", "hybrid"):
            scores = 0.0
        else:
            scores = 1 * L * b_loc * h_loc * S * S * 4
        kv = 2 * L * b_loc * S * h_loc * hd * 2
        return w + acts + scores + kv
    # decode
    w = p_shard * 2
    T = S if not (cfg.family == "hybrid" and S > 65536) else cfg.long_context_window
    if cfg.family == "ssm":
        cache = 2 * L * b_loc * cfg.d_inner / tp * max(1, cfg.ssm_state) * 4
    elif cfg.family == "hybrid":
        n_attn = L // max(1, cfg.hybrid_attn_every)
        cache = n_attn * b_loc * T * h_loc * hd * 2 * 2
        cache += 2 * L * b_loc * (cfg.d_inner / tp) * max(1, cfg.ssm_state) * 4
    else:
        n_attn = L if cfg.family != "moe" else L
        cache = n_attn * b_loc * T * h_loc * hd * 2 * 2  # read k+v (+ring write small)
    acts = 8 * L * b_loc * 1 * D * 2
    return w + cache + acts


# --------------------------------------------------------------------------
# the dry-run of one cell
# --------------------------------------------------------------------------


def run_cell(
    arch: str,
    cell_name: str,
    *,
    multi_pod: bool = False,
    q_chunk: int = 512,
    ssm_chunk: int = 256,
    strategy: str = "megatron",
    save: bool = True,
    verbose: bool = True,
) -> dict:
    cfg = get_config(arch)
    cell = SHAPES[cell_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"

    from repro.models import flags

    if strategy == "zero3":
        # pure DP: model axis joins the batch; no TP, no head padding
        flags.set_tp_pad(1)
        flags.set_batch_axes(("pod", "data", "model"))
    else:
        flags.set_tp_pad(16)  # model-axis size: pad head counts to shard evenly
        flags.set_batch_axes(("pod", "data"))

    abs_params = abstract_params(cfg)
    n_params = count_params(abs_params)
    p_shard = sh.shard_params(abs_params, mesh, cfg, strategy=strategy)
    inputs = input_specs(arch, cell_name)
    in_shard = sh.shard_inputs(inputs, mesh)

    t0 = time.time()
    with mesh:
        if cell.kind == "train":
            abs_opt = jax.eval_shape(
                lambda p: AdamWState(
                    step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p),
                    nu=jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p),
                ),
                abs_params,
            )
            opt_shard = AdamWState(
                step=sh.replicated(mesh),
                mu=jax.tree.map(
                    lambda s: jax.sharding.NamedSharding(mesh, s.spec), p_shard
                ),
                nu=jax.tree.map(
                    lambda s: jax.sharding.NamedSharding(mesh, s.spec), p_shard
                ),
            )
            step = make_train_step(cfg, q_chunk=q_chunk, ssm_chunk=ssm_chunk)
            jitted = jax.jit(step, in_shardings=(p_shard, opt_shard, in_shard))
            lowered = jitted.lower(abs_params, abs_opt, inputs)
        elif cell.kind == "prefill":
            step = make_prefill_step(cfg, q_chunk=q_chunk, ssm_chunk=ssm_chunk)
            if cfg.is_enc_dec:
                jitted = jax.jit(
                    step, in_shardings=(p_shard, in_shard["enc_embeds"], in_shard["tokens"])
                )
                lowered = jitted.lower(abs_params, inputs["enc_embeds"], inputs["tokens"])
            elif cfg.family == "vlm":
                jitted = jax.jit(
                    step,
                    in_shardings=(p_shard, in_shard["tokens"], in_shard["prefix_embeds"]),
                )
                lowered = jitted.lower(abs_params, inputs["tokens"], inputs["prefix_embeds"])
            else:
                jitted = jax.jit(step, in_shardings=(p_shard, in_shard["tokens"]))
                lowered = jitted.lower(abs_params, inputs["tokens"])
        else:  # decode
            B = cell.global_batch
            T = cell.seq_len
            if cfg.family == "hybrid" and T > 65536:
                pass  # ring cache sized inside cache_spec
            abs_cache = abstract_cache(cfg, B, T)
            c_shard = sh.shard_cache(abs_cache, mesh, ssm_version=cfg.ssm_version)
            step = make_serve_step(cfg)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            if cfg.is_enc_dec:
                d = lm.attn_dims(cfg, causal=False)
                enc_T = T  # encoder memory length
                abs_enc_kv = jax.eval_shape(
                    lambda: jax.tree.map(
                        lambda x: jnp.zeros((cfg.dec_layers, *x.shape), x.dtype),
                        {
                            "k": jnp.zeros((B, enc_T, cfg.n_heads, cfg.head_dim), jnp.bfloat16),
                            "v": jnp.zeros((B, enc_T, cfg.n_heads, cfg.head_dim), jnp.bfloat16),
                        },
                    )
                )
                ekv_shard = sh.shard_cache(abs_enc_kv, mesh)
                jitted = jax.jit(
                    step,
                    in_shardings=(p_shard, c_shard, in_shard["tokens"], sh.replicated(mesh), ekv_shard),
                )
                lowered = jitted.lower(abs_params, abs_cache, inputs["tokens"], pos, abs_enc_kv)
            else:
                jitted = jax.jit(
                    step,
                    in_shardings=(p_shard, c_shard, in_shard["tokens"], sh.replicated(mesh)),
                )
                lowered = jitted.lower(abs_params, abs_cache, inputs["tokens"], pos)

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    corrected = hlo_analysis.analyze(hlo)

    # Semantics (calibrated, see EXPERIMENTS.md §Dry-run): post-SPMD HLO
    # shapes are PER-DEVICE, and raw cost_analysis counts while bodies once;
    # `corrected` re-walks the call graph with scan trip counts.  Terms below
    # are per-chip seconds — identical to global/(chips·rate).
    flops_dev = corrected["flops"]
    bytes_dev = corrected["bytes"]
    coll = corrected["collective_bytes"]
    coll_total = coll.get("total", 0.0)
    raw_flops = float(cost.get("flops", 0.0)) if cost else 0.0
    raw_bytes = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
    mf = model_flops(cfg, cell, n_params)

    bytes_model = analytic_bytes(cfg, cell, n_params, n_chips)
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_model / HBM_BW  # TPU-fused traffic model (see analytic_bytes)
    memory_s_parsed = bytes_dev / HBM_BW  # CPU-HLO upper bound
    coll_s = coll_total / LINK_BW
    # XLA's CPU AllReducePromotion pass forcibly widens every bf16 all-reduce
    # to f32 (verified: bypassing it via manual shard_map psum crashes inside
    # that pass).  A TPU deployment all-reduces bf16, so the adjusted term
    # halves the AR payload (other collectives already carry model dtype).
    coll_bf16 = coll_total - 0.5 * coll.get("all-reduce", 0.0)
    coll_s_bf16 = coll_bf16 / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    useful = mf / (flops_dev * n_chips) if flops_dev else None

    def _mem_field(name):
        v = getattr(mem, name, None)
        return int(v) if v is not None else None

    rec = {
        "arch": arch,
        "cell": cell_name,
        "mesh": mesh_name,
        "chips": n_chips,
        "params": n_params,
        "kind": cell.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "analytic_bytes_per_device": bytes_model,
        "memory_s_parsed_upper_bound": memory_s_parsed,
        "raw_cost_analysis": {"flops": raw_flops, "bytes": raw_bytes},
        "collective_bytes_per_device": coll,
        "top_collectives": corrected["top_collectives"][:10],
        "model_flops": mf,
        "useful_flops_ratio": useful,
        "roofline": {**terms, "collective_s_bf16adj": coll_s_bf16, "dominant": dominant},
        "memory_analysis": {
            "output_bytes": _mem_field("output_size_in_bytes"),
            "temp_bytes": _mem_field("temp_size_in_bytes"),
            "argument_bytes": _mem_field("argument_size_in_bytes"),
            "generated_code_bytes": _mem_field("generated_code_size_in_bytes"),
        },
    }

    if verbose:
        print(f"== {arch} x {cell_name} x {mesh_name} ==")
        print(f"  params={n_params/1e9:.2f}B  lower={t_lower:.1f}s compile={t_compile:.1f}s")
        print(f"  memory_analysis: {mem}")
        print(
            f"  per-dev: flops={flops_dev:.3e} bytes={bytes_dev:.3e} coll={coll_total:.3e} "
            f"(AR {coll.get('all-reduce',0):.2e} AG {coll.get('all-gather',0):.2e} "
            f"RS {coll.get('reduce-scatter',0):.2e} A2A {coll.get('all-to-all',0):.2e} "
            f"CP {coll.get('collective-permute',0):.2e})"
        )
        print(
            f"  roofline: compute={compute_s*1e3:.2f}ms memory={memory_s*1e3:.2f}ms "
            f"(parsed-ub {memory_s_parsed*1e3:.0f}ms) collective={coll_s*1e3:.2f}ms "
            f"(bf16-adj {coll_s_bf16*1e3:.2f}ms) "
            f"dominant={dominant} useful_flops_ratio={useful and round(useful, 3)}"
        )

    if save:
        RESULTS.mkdir(parents=True, exist_ok=True)
        suffix = "" if strategy == "megatron" else f"_{strategy}"
        out = RESULTS / f"{arch}_{cell_name}_{mesh_name}{suffix}.json"
        out.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--cell", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--q-chunk", type=int, default=512)
    ap.add_argument("--strategy", type=str, default="megatron", choices=["megatron", "zero3"])
    args = ap.parse_args()

    jobs: list[tuple[str, str, bool]] = []
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    for a in archs:
        cfg = get_config(a)
        cells = runnable_cells(cfg) if (args.all or not args.cell) else [args.cell]
        for c in cells:
            if args.both_meshes:
                jobs.append((a, c, False))
                jobs.append((a, c, True))
            else:
                jobs.append((a, c, args.multi_pod))

    failures = []
    for a, c, mp in jobs:
        try:
            run_cell(a, c, multi_pod=mp, q_chunk=args.q_chunk, strategy=args.strategy)
        except Exception as e:  # noqa: BLE001
            failures.append((a, c, mp, repr(e)[:300]))
            print(f"!! FAILED {a} x {c} multi_pod={mp}: {e}", file=sys.stderr)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print(f"\nALL {len(jobs)} CELLS COMPILED OK")


if __name__ == "__main__":
    main()
