"""Trip-count-aware analysis of compiled (post-SPMD) HLO text.

Why this exists: XLA's ``compiled.cost_analysis()`` counts each ``while``
body ONCE — but every production model here scans over layers (and query
chunks), so a 94-layer stack would be under-counted 94x.  This module parses
``compiled.as_text()`` into computation blocks, recovers scan trip counts
from each while's condition block, and walks the call graph multiplying
per-block costs by their execution count.  It produces:

  * ``flops``            — dot FLOPs (2·prod(out)·prod(contracting dims)),
                           per device (post-SPMD shapes)
  * ``bytes``            — Σ (operand + output bytes) over instructions,
                           fusion-internal blocks excluded (HloCostAnalysis
                           convention), per device
  * ``collective_bytes`` — per kind, tensor bytes crossing links with ring
                           factors (all-reduce 2x, others 1x), per device
  * per-collective-op breakdown for §Perf iteration (who emitted what)

Approximations (documented in EXPERIMENTS.md): condition-block trip counts
assume scan-style ``lt(iter, N)`` bounds (true for every loop we emit);
operand bytes for block parameters resolve through call sites where
unambiguous, else the output-bytes term dominates anyway.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*([\w]+)\[([\d,]*)\][^\s]*\s+([\w\-]+)\("
)
_TUPLE_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*\(.*\)\s+([\w\-]+)\("
)
_PARAM = re.compile(r"%?([\w.\-]+):\s*([\w]+)\[([\d,]*)\]")
_WHILE_REFS = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS = re.compile(r"\(([^)]*)\)")
_CONST = re.compile(r"s32\[\]\s+constant\((\d+)\)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


@dataclasses.dataclass
class Instr:
    name: str
    dtype: str
    dims: tuple[int, ...]
    opcode: str
    line: str

    @property
    def nbytes(self) -> int:
        b = _DTYPE_BYTES.get(self.dtype, 0)
        n = 1
        for d in self.dims:
            n *= d
        return n * b


@dataclasses.dataclass
class Block:
    name: str
    instrs: list[Instr]
    shapes: dict[str, tuple[str, tuple[int, ...]]]  # name -> (dtype, dims)
    lines: list[str]
    is_fusion_body: bool = False


def _dims(s: str) -> tuple[int, ...]:
    return tuple(int(x) for x in s.split(",") if x.strip()) if s.strip() else ()


def parse_blocks(text: str) -> tuple[dict[str, Block], str | None]:
    blocks: dict[str, Block] = {}
    cur: Block | None = None
    entry: str | None = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and "{" in line:
                name = m.group(1)
                cur = Block(name=name, instrs=[], shapes={}, lines=[])
                if line.strip().startswith("ENTRY"):
                    entry = name
                if m.group(2):
                    # tuple-typed params are resolved via get-tuple-element
                    for pm in _PARAM.finditer(m.group(2)):
                        cur.shapes[pm.group(1)] = (pm.group(2), _dims(pm.group(3)))
            continue
        if line.strip() == "}":
            blocks[cur.name] = cur
            cur = None
            continue
        cur.lines.append(line)
        m = _INSTR.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), _dims(m.group(3)), m.group(4), line)
            cur.instrs.append(ins)
            cur.shapes[ins.name] = (ins.dtype, ins.dims)
        else:
            mt = _TUPLE_INSTR.match(line)
            if mt:
                ins = Instr(mt.group(1), "tuple", (), mt.group(2), line)
                cur.instrs.append(ins)
    return blocks, entry


def _trip_count(cond: Block) -> int:
    """Scan-style loops compare the iteration counter to a constant bound."""
    consts = [int(m.group(1)) for ln in cond.lines for m in _CONST.finditer(ln)]
    return max(consts) if consts else 1


def _split_operands(argstr: str) -> list[str]:
    """Operand names from an HLO operand list.  Dumps print either typed
    operands ("f32[8,16]{1,0} %x" — commas inside the dims, names carry %)
    or bare names ("x, y"); handle both."""
    names = re.findall(r"%([\w.\-]+)", argstr)
    if names:
        return names
    return [a.strip() for a in argstr.split(",") if a.strip()]


def _dot_flops(ins: Instr, blk: Block) -> float:
    m = _CONTRACT.search(ins.line)
    if not m:
        return 0.0
    cdims = _dims(m.group(1))
    ops = _OPERANDS.search(ins.line.split(ins.opcode + "(", 1)[1][::-1])
    # operand list: text between the first '(' after opcode and matching ')'
    try:
        args = ins.line.split(ins.opcode + "(", 1)[1]
        args = args.split(")", 1)[0]
        first = _split_operands(args)[0]
    except Exception:
        return 0.0
    lhs = blk.shapes.get(first)
    if lhs is None:
        return 0.0
    k = 1
    for d in cdims:
        if d < len(lhs[1]):
            k *= lhs[1][d]
    out = 1
    for d in ins.dims:
        out *= d
    return 2.0 * out * k


def analyze(text: str) -> dict:
    blocks, entry = parse_blocks(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # mark fusion bodies (excluded from bytes accounting)
    for blk in blocks.values():
        for ins in blk.instrs:
            if ins.opcode == "fusion":
                for m in _CALLS.finditer(ins.line):
                    if m.group(1) in blocks:
                        blocks[m.group(1)].is_fusion_body = True

    # execution multiplier per block, from the call graph
    mult: dict[str, float] = defaultdict(float)

    def visit(name: str, m: float):
        if name not in blocks or m == 0:
            return
        mult[name] += m
        blk = blocks[name]
        for ins in blk.instrs:
            if ins.opcode == "while":
                r = _WHILE_REFS.search(ins.line)
                if r:
                    cond, body = r.group(1), r.group(2)
                    trips = _trip_count(blocks[cond]) if cond in blocks else 1
                    visit(body, m * trips)
                    visit(cond, m * (trips + 1))
            elif ins.opcode == "fusion":
                for c in _CALLS.finditer(ins.line):
                    visit(c.group(1), m)
            elif ins.opcode in ("call", "conditional", "map", "reduce", "sort", "scatter", "reduce-window", "select-and-scatter"):
                for c in _TO_APPLY.finditer(ins.line):
                    visit(c.group(1), m)

    visit(entry, 1.0)

    flops = 0.0
    bytes_acc = 0.0
    coll: dict[str, float] = defaultdict(float)
    coll_ops: list[dict] = []

    # ops with no real memory traffic (views / control), or whose traffic is
    # a slice rather than their full operand (dynamic-slice / DUS ring writes)
    NO_TRAFFIC = {
        "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
        "while", "conditional", "call", "after-all", "iota", "partition-id",
        "replica-id", "reshape",
    }

    def _operand_names(ins: Instr) -> list[str]:
        args = ins.line.split(ins.opcode + "(", 1)
        if len(args) < 2:
            return []
        return _split_operands(args[1].split(")", 1)[0])

    def _shape_bytes(blk: Block, name: str) -> int:
        sh = blk.shapes.get(name)
        if not sh:
            return 0
        n = 1
        for d in sh[1]:
            n *= d
        return n * _DTYPE_BYTES.get(sh[0], 0)

    for name, blk in blocks.items():
        m = mult.get(name, 0.0)
        if m == 0:
            continue
        for ins in blk.instrs:
            if ins.opcode in ("dot", "convolution"):
                flops += m * _dot_flops(ins, blk)
            if not blk.is_fusion_body:
                op = ins.opcode
                if op in NO_TRAFFIC:
                    continue
                if op == "dynamic-slice":
                    b = 2 * ins.nbytes  # read the slice + write it
                elif op == "dynamic-update-slice":
                    ops_ = _operand_names(ins)
                    upd = _shape_bytes(blk, ops_[1]) if len(ops_) > 1 else ins.nbytes
                    b = 3 * upd  # read update + read/write region (in-place)
                elif op == "broadcast":
                    ops_ = _operand_names(ins)
                    b = ins.nbytes + (_shape_bytes(blk, ops_[0]) if ops_ else 0)
                else:
                    b = ins.nbytes + sum(_shape_bytes(blk, a) for a in _operand_names(ins))
                bytes_acc += m * b
            if ins.opcode in COLLECTIVES:
                factor = 2.0 if ins.opcode == "all-reduce" else 1.0
                cb = m * ins.nbytes * factor
                coll[ins.opcode] += cb
                coll_ops.append(
                    {
                        "kind": ins.opcode,
                        "block": name,
                        "mult": m,
                        "tensor_bytes": ins.nbytes,
                        "link_bytes": cb,
                        "meta": ins.line.strip()[:160],
                    }
                )

    coll["total"] = sum(v for k, v in coll.items() if k != "total")
    coll_ops.sort(key=lambda o: -o["link_bytes"])
    return {
        "flops": flops,
        "bytes": bytes_acc,
        "collective_bytes": dict(coll),
        "top_collectives": coll_ops[:20],
        "n_blocks": len(blocks),
    }
