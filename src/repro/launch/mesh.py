"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the 512-device dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (one v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips, DCN/ICI hierarchy on
    the leading axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_data_mesh(n_dev: int | None = None):
    """1-D ``data`` mesh over all (or the first ``n_dev``) devices — the
    shape the keyed/sharded dataplane runs on (docs/protocol.md §6): one
    owner shard per device, no model axis."""
    import jax

    n = n_dev if n_dev is not None else len(jax.devices())
    return compat.make_mesh((n,), ("data",))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return compat.make_mesh(shape, axes)
