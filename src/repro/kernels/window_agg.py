"""Pallas TPU kernel: windowed event aggregation (the WCRDT fold hot path).

TPU adaptation of the paper's per-event insert loop (DESIGN.md §5): the
scatter becomes a **one-hot contraction** so the MXU does the segment
reduction —

    sum/count :  out[w(,c)] += Σ_b onehot_w[b,w] · v[b] (· onehot_c[b,c])
                 → a [bt,W]ᵀ×[bt,C] matmul per event tile (MXU), or a
                   masked-broadcast reduce for the unkeyed case (VPU),
    max/min   :  masked broadcast + reduce over the event tile (VPU).

Grid: one program per event tile of ``bt`` events; the [W(,C)] window state
stays resident in VMEM across the whole grid (accumulator revisiting), so
HBM traffic is events-in + state once.

Tiling notes: bt is a multiple of 8 (sublane), W·C lanes padded to 128 by the
caller (ops.py); fp32 accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEUTRAL = {"sum": 0.0, "count": 0.0, "max": -jnp.inf, "min": jnp.inf}


def _kernel_unkeyed(vals_ref, slots_ref, mask_ref, out_ref, *, op: str, W: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, NEUTRAL[op])

    v = vals_ref[...].astype(jnp.float32)  # [bt]
    if op == "count":
        v = jnp.ones_like(v)
    m = mask_ref[...]
    slots = slots_ref[...]
    onehot = slots[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)
    onehot = onehot & m[:, None]  # [bt, W]
    if op in ("sum", "count"):
        out_ref[...] += jnp.sum(jnp.where(onehot, v[:, None], 0.0), axis=0)
    elif op == "max":
        tile = jnp.max(jnp.where(onehot, v[:, None], -jnp.inf), axis=0)
        out_ref[...] = jnp.maximum(out_ref[...], tile)
    else:
        tile = jnp.min(jnp.where(onehot, v[:, None], jnp.inf), axis=0)
        out_ref[...] = jnp.minimum(out_ref[...], tile)


def _kernel_keyed(vals_ref, slots_ref, keys_ref, mask_ref, out_ref, *, op: str, W: int, C: int):
    """Keyed sum via MXU: out[W, C] += onehot_wᵀ @ (v ⊙ onehot_c)."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, NEUTRAL[op])

    v = vals_ref[...].astype(jnp.float32)
    if op == "count":
        v = jnp.ones_like(v)
    m = mask_ref[...]
    slots, keys = slots_ref[...], keys_ref[...]
    bt = v.shape[0]
    oh_w = (slots[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)) & m[:, None]
    oh_c = keys[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, C), 1)
    if op in ("sum", "count"):
        rhs = jnp.where(oh_c, v[:, None], 0.0)  # [bt, C]
        out_ref[...] += jax.lax.dot_general(
            oh_w.astype(jnp.float32),
            rhs,
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    else:
        # max/min: VPU masked reduce, strip-mined one W row at a time — the
        # live intermediate is [bt, C], never the [bt, W, C] broadcast that
        # would OOM at moderate C (peak pinned by tests/test_segment_reduce.py)
        for w in range(W):
            strip = jnp.where(oh_w[:, w][:, None] & oh_c, v[:, None], NEUTRAL[op])
            if op == "max":
                out_ref[w, :] = jnp.maximum(out_ref[w, :], jnp.max(strip, axis=0))
            else:
                out_ref[w, :] = jnp.minimum(out_ref[w, :], jnp.min(strip, axis=0))


def window_agg_pallas(
    vals: jax.Array,
    slots: jax.Array,
    mask: jax.Array,
    W: int,
    op: str = "sum",
    keys: jax.Array | None = None,
    C: int = 1,
    block_b: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Returns [W] (unkeyed) or [W, C] (keyed) fp32 aggregates.

    Accepts any event-lane count ``B`` — in particular the ``B*K`` expanded
    multi-emit stream of an overlapping window assigner (DESIGN.md §8),
    which is rarely a block multiple.  Lanes are padded up to ``block_b``
    with ``mask=False`` (inert under every op's neutral element), so the
    fold is agnostic to whether lanes came from distinct events or one
    event multi-emitted into several windows.
    """
    B = vals.shape[0]
    pad = (-B) % block_b
    if pad:
        vals = jnp.pad(vals, (0, pad))
        slots = jnp.pad(slots, (0, pad))  # slot 0; dead under mask=False
        mask = jnp.pad(mask, (0, pad))  # False
        if keys is not None:
            keys = jnp.pad(keys, (0, pad))
        B += pad
    grid = (B // block_b,)
    ev_spec = pl.BlockSpec((block_b,), lambda i: (i,))
    if keys is None:
        out_spec = pl.BlockSpec((W,), lambda i: (0,))
        fn = functools.partial(_kernel_unkeyed, op=op, W=W)
        return pl.pallas_call(
            fn,
            grid=grid,
            in_specs=[ev_spec, ev_spec, ev_spec],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((W,), jnp.float32),
            interpret=interpret,
        )(vals, slots, mask)
    out_spec = pl.BlockSpec((W, C), lambda i: (0, 0))
    fn = functools.partial(_kernel_keyed, op=op, W=W, C=C)
    return pl.pallas_call(
        fn,
        grid=grid,
        in_specs=[ev_spec, ev_spec, ev_spec, ev_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((W, C), jnp.float32),
        interpret=interpret,
    )(vals, slots, keys, mask)
