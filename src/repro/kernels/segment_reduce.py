"""Pallas TPU kernel: sorted segment reduce (the sparse keyed fold path).

The dense keyed ``window_agg`` kernel contracts a ``[bt, C]`` one-hot per
event tile — O(B·C) work and a ``[W, C]`` VMEM-resident accumulator — which
stops winning (and then stops fitting) as the key cardinality C grows past a
few thousand.  This kernel is the million-key replacement (DESIGN.md §5):

  1. the wrapper maps masked lanes to a sentinel segment and sorts the
     ``(segment, value)`` pairs by segment id (``lax.sort_key_val`` — one
     O(B log B) pass, done in XLA where the TPU sort is already tuned),
  2. a prefix-sum/searchsorted pass turns the sorted stream into per-output-
     tile ``(start, count)`` event ranges, shipped as scalar-prefetch args,
  3. the kernel grid runs one program per *segment tile* of ``seg_tile``
     outputs; each program walks only its own event range in fixed ``bt``
     chunks (dynamic ``pl.ds`` loads from the VMEM-resident sorted stream)
     and reduces each chunk against a ``[bt, seg_tile]`` relative one-hot.

Work is O(events · seg_tile / bt) + one partial chunk per non-empty tile —
independent of total C — and VMEM holds one ``[seg_tile]`` accumulator
instead of the whole ``[W, C]`` state, so the output can be arbitrarily
large (it streams through HBM tile by tile).  Empty tiles never enter the
chunk loop and just write the neutral element.

``kernels/ops.py`` dispatches the keyed ``window_agg`` here above
``SPARSE_KEY_THRESHOLD`` keys with ``segment = slot * C + key``; the sharded
keyed dataplane (docs/protocol.md §6) keeps per-device C small enough that
its ``[W, C/n_dev]`` range stays VMEM-resident anyway.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEUTRAL = {"sum": 0.0, "count": 0.0, "max": -jnp.inf, "min": jnp.inf}


def _kernel(
    start_ref, count_ref, vals_ref, segs_ref, out_ref, *,
    op: str, seg_tile: int, bt: int,
):
    j = pl.program_id(0)
    base = start_ref[j]
    cnt = count_ref[j]
    tile_lo = j * seg_tile
    neutral = jnp.float32(NEUTRAL[op])

    def chunk(i, acc):
        off = base + i * bt
        v = vals_ref[pl.ds(off, bt)].astype(jnp.float32)
        if op == "count":
            v = jnp.ones_like(v)
        sg = segs_ref[pl.ds(off, bt)]
        # lanes beyond the range's end are padding (sentinel segments would
        # mask them too, but the explicit bound keeps the last chunk exact)
        live = (jax.lax.broadcasted_iota(jnp.int32, (bt, seg_tile), 0) + i * bt) < cnt
        rel = sg - tile_lo
        oh = (
            rel[:, None] == jax.lax.broadcasted_iota(jnp.int32, (bt, seg_tile), 1)
        ) & live
        contrib = jnp.where(oh, v[:, None], neutral)
        if op in ("sum", "count"):
            return acc + jnp.sum(contrib, axis=0)
        if op == "max":
            return jnp.maximum(acc, jnp.max(contrib, axis=0))
        return jnp.minimum(acc, jnp.min(contrib, axis=0))

    acc0 = jnp.full((seg_tile,), neutral, dtype=jnp.float32)
    n_chunks = pl.cdiv(cnt, bt)
    out_ref[...] = jax.lax.fori_loop(0, n_chunks, chunk, acc0)


def segment_reduce_pallas(
    vals: jax.Array,  # [B] any numeric dtype
    segs: jax.Array,  # i32[B] in [0, n_seg)
    mask: jax.Array,  # bool[B]
    n_seg: int,
    op: str = "sum",
    seg_tile: int = 512,
    bt: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Returns f32[n_seg]: per-segment sum/count/max/min of the masked lanes.

    Segments no lane touches read the op's neutral element (0 for sum/count,
    ∓inf for max/min) — same convention as ``ref.segment_reduce_ref``.
    Input order is irrelevant: the wrapper sorts by segment id before the
    kernel runs, so callers may hand over raw scatter streams.
    """
    B = vals.shape[0]
    n_tiles = pl.cdiv(n_seg, seg_tile)
    n_seg_pad = n_tiles * seg_tile
    sentinel = jnp.int32(n_seg_pad)  # beyond every tile: masked lanes sort last
    seg_m = jnp.where(mask, segs.astype(jnp.int32), sentinel)
    sseg, sval = jax.lax.sort_key_val(seg_m, vals.astype(jnp.float32))
    # pad by one chunk so the last dynamic load never runs off the stream
    sseg = jnp.pad(sseg, (0, bt), constant_values=n_seg_pad)
    sval = jnp.pad(sval, (0, bt))
    bounds = jnp.arange(n_tiles + 1, dtype=jnp.int32) * seg_tile
    edges = jnp.searchsorted(sseg[: B], bounds, side="left").astype(jnp.int32)
    starts, counts = edges[:-1], edges[1:] - edges[:-1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((B + bt,), lambda j, *_: (0,)),
            pl.BlockSpec((B + bt,), lambda j, *_: (0,)),
        ],
        out_specs=pl.BlockSpec((seg_tile,), lambda j, *_: (j,)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, op=op, seg_tile=seg_tile, bt=bt),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_seg_pad,), jnp.float32),
        interpret=interpret,
    )(starts, counts, sval, sseg)
    return out[:n_seg]
