"""Pallas TPU kernel: per-window bounded top-k merge (Q7 "highest bids").

Grid: one program per window.  Each program masks the event tile to its
window and folds it into the window's running top-k by k rounds of
max-extraction (k <= 16, so k sequential VPU reductions beat a full sort;
lexicographic (val, id) order keeps the lattice deterministic).  The [W, k]
state stays VMEM-resident; events stream once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = float("-inf")  # python literal: pallas kernels must not capture arrays


def _kernel(sv_ref, si_ref, vals_ref, ids_ref, slots_ref, mask_ref, ov_ref, oi_ref, *, k: int):
    w = pl.program_id(0)
    m = mask_ref[...] & (slots_ref[...] == w)
    bv = jnp.where(m, vals_ref[...].astype(jnp.float32), NEG)  # [B]
    bi = jnp.where(m, ids_ref[...], 0).astype(jnp.uint32)
    cv = jnp.concatenate([sv_ref[...].reshape(-1), bv])  # [k + B]
    ci = jnp.concatenate([si_ref[...].reshape(-1), bi])

    out_v = jnp.zeros((k,), jnp.float32)
    out_i = jnp.zeros((k,), jnp.uint32)
    for j in range(k):  # k rounds of lexicographic argmax-extract
        # order by (val, id): strictly larger val wins; ties -> larger id
        best_v = jnp.max(cv)
        is_best_v = cv == best_v
        best_i = jnp.max(jnp.where(is_best_v, ci, 0))
        out_v = out_v.at[j].set(best_v)
        out_i = out_i.at[j].set(best_i)
        taken = is_best_v & (ci == best_i)
        # remove exactly the taken entries (dedups identical (v, id) pairs —
        # set semantics of the TopK lattice)
        cv = jnp.where(taken, NEG, cv)
        ci = jnp.where(taken, 0, ci)
    ov_ref[...] = out_v.reshape(1, k)
    oi_ref[...] = out_i.reshape(1, k)


def topk_window_pallas(
    state_vals: jax.Array,  # f32[W, k]
    state_ids: jax.Array,  # u32[W, k]
    vals: jax.Array,  # f32[B]
    ids: jax.Array,  # u32[B]
    slots: jax.Array,  # i32[B]
    mask: jax.Array,  # bool[B]
    interpret: bool = False,
):
    W, k = state_vals.shape
    B = vals.shape[0]
    ev = pl.BlockSpec((B,), lambda w: (0,))
    st = pl.BlockSpec((1, k), lambda w: (w, 0))
    ov, oi = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=(W,),
        in_specs=[st, st, ev, ev, ev, ev],
        out_specs=[st, st],
        out_shape=[
            jax.ShapeDtypeStruct((W, k), jnp.float32),
            jax.ShapeDtypeStruct((W, k), jnp.uint32),
        ],
        interpret=interpret,
    )(state_vals, state_ids, vals, ids, slots, mask)
    return ov, oi
