"""Pure-jnp oracles for every kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def window_agg_ref(
    vals: jax.Array,  # f32[B]
    slots: jax.Array,  # i32[B] in [0, W)
    mask: jax.Array,  # bool[B]
    W: int,
    op: str = "sum",
    keys: jax.Array | None = None,  # i32[B] in [0, C) (keyed aggregation)
    C: int = 1,
    init: jax.Array | None = None,  # [W] or [W, C] running state
):
    """Fold a batch of events into per-window (optionally per-key) aggregates.

    Lane-count agnostic: ``B`` may be the raw batch or the ``B*K`` expanded
    multi-emit stream of an overlapping window assigner
    (``core.window.expand_events``) — out-of-window expansion lanes arrive
    with ``mask=False`` and fold to the op's neutral element."""
    neutral = {"sum": 0.0, "count": 0.0, "max": -jnp.inf, "min": jnp.inf}[op]
    v = vals.astype(jnp.float32)
    if op == "count":
        v = jnp.ones_like(v)
    v = jnp.where(mask, v, neutral)
    if keys is None:
        seg = slots
        n_seg = W
        shape = (W,)
    else:
        seg = slots * C + keys
        n_seg = W * C
        shape = (W, C)
    if op in ("sum", "count"):
        out = jax.ops.segment_sum(v, seg, num_segments=n_seg)
    elif op == "max":
        out = jax.ops.segment_max(v, seg, num_segments=n_seg)
        out = jnp.maximum(out, -jnp.inf)
    else:
        out = jax.ops.segment_min(v, seg, num_segments=n_seg)
        out = jnp.minimum(out, jnp.inf)
    out = out.reshape(shape)
    if init is not None:
        if op in ("sum", "count"):
            out = out + init
        elif op == "max":
            out = jnp.maximum(out, init)
        else:
            out = jnp.minimum(out, init)
    return out


def segment_reduce_ref(
    vals: jax.Array,  # [B] any numeric dtype
    segs: jax.Array,  # i32[B] in [0, n_seg)
    mask: jax.Array,  # bool[B]
    n_seg: int,
    op: str = "sum",
) -> jax.Array:
    """f32[n_seg] per-segment sum/count/max/min of the masked lanes.

    Untouched segments read the op's neutral element (0 for sum/count, ∓inf
    for max/min).  Masked lanes are routed to a sentinel segment past the
    output and sliced away, so ``segs`` under a False mask may be garbage.
    """
    v = vals.astype(jnp.float32)
    if op == "count":
        v = jnp.ones_like(v)
    seg = jnp.where(mask, segs.astype(jnp.int32), jnp.int32(n_seg))
    if op in ("sum", "count"):
        out = jax.ops.segment_sum(v, seg, num_segments=n_seg + 1)
    elif op == "max":
        out = jnp.maximum(jax.ops.segment_max(v, seg, num_segments=n_seg + 1), -jnp.inf)
    else:
        out = jnp.minimum(jax.ops.segment_min(v, seg, num_segments=n_seg + 1), jnp.inf)
    return out[:n_seg]


def crdt_merge_ref(stack: jax.Array, op: str = "max") -> jax.Array:
    """Lattice join of R replica states: reduce over axis 0.

    stack: [R, ...]; op in {max, min, or, sum-slots (per-actor max is 'max')}.
    """
    if op == "max":
        return jnp.max(stack, axis=0)
    if op == "min":
        return jnp.min(stack, axis=0)
    if op == "or":
        return jnp.bitwise_or.reduce(stack, axis=0) if stack.dtype != jnp.bool_ else jnp.any(stack, axis=0)
    raise ValueError(op)


def gated_neutral(op: str, dtype) -> jnp.ndarray:
    """Join identity for a gated-out replica contribution."""
    if op == "or":
        return jnp.zeros((), dtype=dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(-jnp.inf if op == "max" else jnp.inf, dtype=dtype)
    info = jnp.iinfo(dtype)
    return jnp.array(info.min if op == "max" else info.max, dtype=dtype)


def gated_delta_merge_ref(
    wid_stack: jax.Array,  # i32[R, W] per-replica ring tenant wids (-1 clean)
    leaf_stack: jax.Array,  # [R, W, ...] matching window-leaf stack
    op: str = "max",
) -> jax.Array:
    """Slot-aware join of R delta replicas: per slot, only replicas holding
    the newest tenant window contribute; stale/clean replicas (including
    ``slot_wid == -1``) are gated to the join identity.  All-clean slots
    (every wid -1) pass replica 0 through — deltas carry the deterministic
    zero-state there, identical on every replica.
    """
    out_wid = jnp.max(wid_stack, axis=0)  # [W]
    gate = wid_stack == out_wid[None, :]  # [R, W]
    extra = (1,) * (leaf_stack.ndim - 2)
    g = gate.reshape(*gate.shape, *extra)
    x = jnp.where(g, leaf_stack, gated_neutral(op, leaf_stack.dtype))
    if op == "max":
        return jnp.max(x, axis=0)
    if op == "min":
        return jnp.min(x, axis=0)
    if op == "or":
        if leaf_stack.dtype == jnp.bool_:
            return jnp.any(x, axis=0)
        return jnp.bitwise_or.reduce(x, axis=0)
    raise ValueError(op)


def topk_window_ref(
    state_vals: jax.Array,  # f32[W, k] desc-sorted, -inf padded
    state_ids: jax.Array,  # u32[W, k]
    vals: jax.Array,  # f32[B]
    ids: jax.Array,  # u32[B]
    slots: jax.Array,  # i32[B]
    mask: jax.Array,  # bool[B]
):
    """Per-window top-k merge of a batch into the running state (Q7)."""
    W, k = state_vals.shape

    def per_window(w, sv, si):
        m = mask & (slots == w)
        bv = jnp.where(m, vals.astype(jnp.float32), -jnp.inf)
        bi = jnp.where(m, ids, 0).astype(jnp.uint32)
        cv = jnp.concatenate([sv, bv])
        ci = jnp.concatenate([si, bi])
        svv, sii = jax.lax.sort((cv, ci), dimension=0, num_keys=2)
        return svv[-k:][::-1], sii[-k:][::-1]

    return jax.vmap(per_window)(jnp.arange(W), state_vals, state_ids)
