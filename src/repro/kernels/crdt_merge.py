"""Pallas TPU kernel: tiled lattice join of replica stacks.

The gossip/merge hot path (DESIGN.md §5): join R replica states leaf-by-leaf
with an elementwise MAX / MIN / OR reduction over the replica axis.  The
feature dimension is tiled [tile_f] along VMEM lanes; each grid program loads
an [R, tile_f] block and reduces it in registers — HBM traffic is exactly
read-once + write-once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import gated_neutral


def _kernel(stack_ref, out_ref, *, op: str):
    x = stack_ref[...]  # [R, tile_f]
    if op == "max":
        out_ref[...] = jnp.max(x, axis=0)
    elif op == "min":
        out_ref[...] = jnp.min(x, axis=0)
    elif op == "or":
        r = x[0]
        for i in range(1, x.shape[0]):
            r = jnp.bitwise_or(r, x[i])
        out_ref[...] = r
    else:
        raise ValueError(op)


def crdt_merge_pallas(
    stack: jax.Array,  # [R, F] (leaf flattened by ops.py)
    op: str = "max",
    tile_f: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    R, F = stack.shape
    assert F % tile_f == 0, (F, tile_f)
    grid = (F // tile_f,)
    return pl.pallas_call(
        functools.partial(_kernel, op=op),
        grid=grid,
        in_specs=[pl.BlockSpec((R, tile_f), lambda i: (0, i))],
        out_specs=pl.BlockSpec((tile_f,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((F,), stack.dtype),
        interpret=interpret,
    )(stack)


# ---------------------------------------------------------------------------
# Gated delta merge: slot-aware join of delta-state replicas (DESIGN.md §6).
#
# Delta sync ships rings whose untouched slots carry slot_wid = -1 and zero
# contents.  Joining R such deltas per slot means: replicas whose tenant
# window trails the per-slot max (stale tenants and clean slots alike) must
# NOT contribute — their content belongs to an older window.  The kernel
# loads a [R, tile_w, tile_f] block plus its [R, tile_w] wid block, computes
# the per-slot winner mask on the VPU, and reduces gated lanes in registers.
# Blocks whose every slot is clean skip the masked reduce entirely and copy
# replica 0 (all deltas hold the identical deterministic zero-state there).
# ---------------------------------------------------------------------------


def _gated_kernel(wid_ref, stack_ref, out_ref, *, op: str):
    wid = wid_ref[...]  # i32[R, tile_w]
    top = jnp.max(wid, axis=0)  # i32[tile_w]
    any_dirty = jnp.max(top) >= 0

    @pl.when(any_dirty)
    def _dirty():
        x = stack_ref[...]  # [R, tile_w, tile_f]
        gate = (wid == top[None, :])[..., None]  # [R, tile_w, 1]
        xg = jnp.where(gate, x, gated_neutral(op, x.dtype))
        if op == "max":
            out_ref[...] = jnp.max(xg, axis=0)
        elif op == "min":
            out_ref[...] = jnp.min(xg, axis=0)
        elif op == "or":
            r = xg[0]
            for i in range(1, xg.shape[0]):
                r = jnp.bitwise_or(r, xg[i])
            out_ref[...] = r
        else:
            raise ValueError(op)

    @pl.when(jnp.logical_not(any_dirty))
    def _clean():
        # every replica's block is clean zero-state: copy, skip the reduce
        out_ref[...] = stack_ref[0]


def gated_delta_merge_pallas(
    wid_stack: jax.Array,  # i32[R, W]
    stack: jax.Array,  # [R, W, F] (trailing dims flattened by ops.py)
    op: str = "max",
    tile_w: int = 8,
    tile_f: int = 128,
    interpret: bool = False,
) -> jax.Array:
    R, W, F = stack.shape
    assert wid_stack.shape == (R, W), (wid_stack.shape, stack.shape)
    assert W % tile_w == 0 and F % tile_f == 0, (W, F, tile_w, tile_f)
    grid = (W // tile_w, F // tile_f)
    return pl.pallas_call(
        functools.partial(_gated_kernel, op=op),
        grid=grid,
        in_specs=[
            pl.BlockSpec((R, tile_w), lambda i, j: (0, i)),
            pl.BlockSpec((R, tile_w, tile_f), lambda i, j: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((tile_w, tile_f), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((W, F), stack.dtype),
        interpret=interpret,
    )(wid_stack, stack)
