"""Pallas TPU kernel: tiled lattice join of replica stacks.

The gossip/merge hot path (DESIGN.md §5): join R replica states leaf-by-leaf
with an elementwise MAX / MIN / OR reduction over the replica axis.  The
feature dimension is tiled [tile_f] along VMEM lanes; each grid program loads
an [R, tile_f] block and reduces it in registers — HBM traffic is exactly
read-once + write-once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(stack_ref, out_ref, *, op: str):
    x = stack_ref[...]  # [R, tile_f]
    if op == "max":
        out_ref[...] = jnp.max(x, axis=0)
    elif op == "min":
        out_ref[...] = jnp.min(x, axis=0)
    elif op == "or":
        r = x[0]
        for i in range(1, x.shape[0]):
            r = jnp.bitwise_or(r, x[i])
        out_ref[...] = r
    else:
        raise ValueError(op)


def crdt_merge_pallas(
    stack: jax.Array,  # [R, F] (leaf flattened by ops.py)
    op: str = "max",
    tile_f: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    R, F = stack.shape
    assert F % tile_f == 0, (F, tile_f)
    grid = (F // tile_f,)
    return pl.pallas_call(
        functools.partial(_kernel, op=op),
        grid=grid,
        in_specs=[pl.BlockSpec((R, tile_f), lambda i: (0, i))],
        out_specs=pl.BlockSpec((tile_f,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((F,), stack.dtype),
        interpret=interpret,
    )(stack)
