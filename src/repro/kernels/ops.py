"""Jitted public wrappers for the Pallas kernels.

Dispatch policy: `pl.pallas_call` lowers natively on TPU; elsewhere the
wrappers fall back to the jnp reference (bit-identical semantics), keeping
the 512-device CPU dry-run pure XLA.  Tests exercise the kernels with
``interpret=True`` against the refs across shape/dtype sweeps.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.crdt_merge import crdt_merge_pallas, gated_delta_merge_pallas
from repro.kernels.segment_reduce import segment_reduce_pallas
from repro.kernels.topk_window import topk_window_pallas
from repro.kernels.window_agg import window_agg_pallas

# Keyed cardinality above which the dense one-hot MXU kernel loses to the
# sorted segment-reduce kernel: the dense path does O(B·C) work per tile and
# needs a [W, C] VMEM accumulator, while the sparse path's work is
# C-independent (DESIGN.md §5).  Below the threshold the dense kernel keeps
# its MXU contraction AND its bit-identical small-C behaviour.
SPARSE_KEY_THRESHOLD = 1024


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("n_seg", "op", "use_pallas", "interpret"))
def segment_reduce(
    vals, segs, mask, n_seg: int, op: str = "sum",
    use_pallas: bool | None = None, interpret: bool = False,
):
    """Per-segment sum/count/max/min of the masked lanes -> f32[n_seg].

    Pallas on TPU (sorted one-pass reduce, kernels/segment_reduce.py), jnp
    segment ops elsewhere; untouched segments read the op's neutral element.
    """
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return segment_reduce_pallas(vals, segs, mask, n_seg, op=op, interpret=interpret)
    return _ref.segment_reduce_ref(vals, segs, mask, n_seg, op=op)


@partial(jax.jit, static_argnames=("W", "op", "C", "use_pallas", "interpret"))
def window_agg(
    vals, slots, mask, W: int, op: str = "sum", keys=None, C: int = 1,
    init=None, use_pallas: bool | None = None, interpret: bool = False,
):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        if keys is not None and C >= SPARSE_KEY_THRESHOLD:
            # high-cardinality keyed fold: flatten (slot, key) into segment
            # ids and ride the sorted segment-reduce kernel — the dense
            # [bt, C] one-hot would do O(B·C) work and outgrow VMEM
            if W * C >= 2**31:
                raise ValueError(
                    f"W*C = {W * C} overflows i32 segment ids; shard the key "
                    "range first (docs/protocol.md §6)"
                )
            seg = slots * jnp.int32(C) + keys
            out = segment_reduce_pallas(
                vals, seg, mask, W * C, op=op, interpret=interpret
            ).reshape(W, C)
        else:
            out = window_agg_pallas(
                vals, slots, mask, W, op=op, keys=keys, C=C, interpret=interpret
            )
        if init is not None:
            if op in ("sum", "count"):
                out = out + init
            elif op == "max":
                out = jnp.maximum(out, init)
            else:
                out = jnp.minimum(out, init)
        return out
    return _ref.window_agg_ref(vals, slots, mask, W, op=op, keys=keys, C=C, init=init)


@partial(jax.jit, static_argnames=("op", "use_pallas", "interpret"))
def crdt_merge(stack, op: str = "max", use_pallas: bool | None = None, interpret: bool = False):
    """Join [R, ...] replica stack along axis 0 (flattens trailing dims)."""
    use = _on_tpu() if use_pallas is None else use_pallas
    if not use:
        return _ref.crdt_merge_ref(stack, op=op)
    R = stack.shape[0]
    trailing = stack.shape[1:]
    flat = stack.reshape(R, -1)
    F = flat.shape[1]
    tile = 1024
    pad = (-F) % tile
    if pad:
        fill = {"max": -jnp.inf, "min": jnp.inf, "or": 0}[op]
        if not jnp.issubdtype(flat.dtype, jnp.floating):
            fill = 0
        flat = jnp.pad(flat, ((0, 0), (0, pad)), constant_values=fill)
    out = crdt_merge_pallas(flat, op=op, tile_f=tile, interpret=interpret)
    return out[:F].reshape(trailing)


@partial(jax.jit, static_argnames=("op", "use_pallas", "interpret"))
def gated_delta_merge(
    wid_stack, leaf_stack, op: str = "max", use_pallas: bool | None = None,
    interpret: bool = False,
):
    """Slot-aware join of [R]-stacked delta replicas (delta-state sync).

    ``wid_stack`` i32[R, W] carries each replica's ring tenant wids (-1 for
    clean slots); ``leaf_stack`` [R, W, ...] the matching window leaf.  Per
    slot only newest-tenant replicas contribute; all-clean tiles are copied,
    not reduced (the Pallas kernel's skip path).
    """
    use = _on_tpu() if use_pallas is None else use_pallas
    if not use:
        return _ref.gated_delta_merge_ref(wid_stack, leaf_stack, op=op)
    R, W = wid_stack.shape
    trailing = leaf_stack.shape[2:]
    flat = leaf_stack.reshape(R, W, -1)
    F = flat.shape[2]
    tile_w = 8 if W % 8 == 0 else 1
    tile_f = 128
    pad_f = (-F) % tile_f
    if pad_f:
        # pad lanes join to garbage that is sliced away; 0 keeps math finite
        flat = jnp.pad(flat, ((0, 0), (0, 0), (0, pad_f)))
    out = gated_delta_merge_pallas(
        wid_stack, flat, op=op, tile_w=tile_w, tile_f=tile_f, interpret=interpret
    )
    return out[:, :F].reshape(W, *trailing)


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def topk_window(
    state_vals, state_ids, vals, ids, slots, mask,
    use_pallas: bool | None = None, interpret: bool = False,
):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return topk_window_pallas(
            state_vals, state_ids, vals, ids, slots, mask, interpret=interpret
        )
    return _ref.topk_window_ref(state_vals, state_ids, vals, ids, slots, mask)
