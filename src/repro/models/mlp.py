"""Dense FFN (SwiGLU) — the megatron-TP workhorse."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, shard


def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff), dtype),
        "w_up": dense_init(ks[1], (d_model, d_ff), dtype),
        "w_down": dense_init(ks[2], (d_ff, d_model), dtype, fan_in=d_ff),
    }


def mlp_ffn(params, x: jax.Array) -> jax.Array:
    if x.ndim == 3 and x.shape[1] == 1:
        # decode (S==1): 2D weight-stationary plan — keep the residual stream
        # D-sharded over `data` so both matmuls contract sharded dims in
        # place.  Weights never move; the collectives are MB-scale activation
        # psums instead of per-token FSDP weight gathers (EXPERIMENTS.md
        # §Perf iteration B: 611ms -> ~60ms collective term on mistral-123b).
        x = shard(x, None, None, ("data",))
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
        h = shard(h, None, None, "model")
        y = h @ params["w_down"]
        return shard(y, None, None, ("data",))
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    # TP: d_ff over model — and batch over (pod, data): leaving batch
    # unconstrained here let GSPMD pick a full-batch-gather plan for the
    # remat'd backward (412 GB/step/device; EXPERIMENTS.md §Perf iter 1).
    if h.ndim == 3:
        h = shard(h, ("pod", "data"), None, "model")
    else:
        h = shard(h, *((None,) * (h.ndim - 1)), "model")
    return h @ params["w_down"]
