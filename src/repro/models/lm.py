"""Model assembly: every assigned architecture as init / train-forward /
prefill / decode, built from the layer library.

Families:
  dense / vlm        : decoder-only transformer (GQA + SwiGLU); vlm prepends a
                       stub patch-embedding prefix to the token embeddings.
  moe                : same skeleton, FFN replaced by MoE every
                       ``moe_every``-th layer (macro-layer scan keeps the
                       stack homogeneous for lax.scan).
  ssm                : pure Mamba1 stack (attention-free).
  hybrid             : Mamba2 stack with ONE shared-weight attention block
                       applied after every ``hybrid_attn_every`` mamba layers
                       (zamba2); macro-scan of [every x mamba + shared attn],
                       plus an unscanned tail of mamba layers.
  audio (enc-dec)    : bidirectional encoder over stub frame embeddings +
                       causal decoder with cross-attention (seamless).

All stacks scan over layers with stacked params (HLO depth O(1)) and
``jax.checkpoint`` on the layer body for training.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import AttnDims
from repro.models.common import (
    BATCH,
    embed,
    embed_init,
    init_rmsnorm,
    rmsnorm,
    shard,
    unembed_logits,
    unembed_loss,
)
from repro.models.mlp import init_mlp, mlp_ffn
from repro.models.moe import MoEDims
from repro.models.ssm import SSMDims


# --------------------------------------------------------------------------
# dims helpers
# --------------------------------------------------------------------------


def attn_dims(cfg: ArchConfig, causal: bool = True, sliding: int | None = None) -> AttnDims:
    from repro.models import flags

    return AttnDims(
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim,
        d_model=cfg.d_model,
        rope_theta=cfg.rope_theta,
        sliding_window=cfg.sliding_window if sliding is None else sliding,
        causal=causal,
        n_heads_padded=flags.pad_heads(cfg.n_heads),
    )


def moe_dims(cfg: ArchConfig) -> MoEDims:
    return MoEDims(
        d_model=cfg.d_model,
        n_experts=cfg.moe_experts,
        top_k=cfg.moe_top_k,
        d_ff=cfg.moe_d_ff or cfg.d_ff,
        capacity_factor=cfg.capacity_factor,
        shared_expert=cfg.moe_shared_expert,
        shared_d_ff=cfg.d_ff,
    )


def ssm_dims(cfg: ArchConfig) -> SSMDims:
    return SSMDims(
        d_model=cfg.d_model,
        d_state=cfg.ssm_state,
        d_conv=cfg.ssm_conv,
        expand=cfg.ssm_expand,
        version=cfg.ssm_version,
        n_heads=cfg.ssm_heads,
    )


# --------------------------------------------------------------------------
# single blocks
# --------------------------------------------------------------------------


def init_dense_block(key, cfg: ArchConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn.init_attn(k1, attn_dims(cfg), dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def dense_block(p, cfg: ArchConfig, h, *, q_chunk=512):
    h = h + attn.attn_train(p["attn"], attn_dims(cfg), rmsnorm(h, p["ln1"], cfg.norm_eps), q_chunk=q_chunk)
    h = h + mlp_ffn(p["mlp"], rmsnorm(h, p["ln2"], cfg.norm_eps))
    return h


def init_moe_block(key, cfg: ArchConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn.init_attn(k1, attn_dims(cfg), dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "moe": moe_lib.init_moe(k2, moe_dims(cfg), dtype),
    }


def moe_block(p, cfg: ArchConfig, h, *, q_chunk=512):
    h = h + attn.attn_train(p["attn"], attn_dims(cfg), rmsnorm(h, p["ln1"], cfg.norm_eps), q_chunk=q_chunk)
    h = h + moe_lib.moe_ffn(p["moe"], moe_dims(cfg), rmsnorm(h, p["ln2"], cfg.norm_eps))
    return h


def init_mamba_block(key, cfg: ArchConfig, dtype) -> dict:
    return {
        "ln": init_rmsnorm(cfg.d_model, dtype),
        "mamba": ssm_lib.init_mamba(key, ssm_dims(cfg), dtype),
    }


def mamba_block(p, cfg: ArchConfig, h, state=None, conv=None, chunk=256):
    y, st = ssm_lib.mamba_forward(
        p["mamba"], ssm_dims(cfg), rmsnorm(h, p["ln"], cfg.norm_eps),
        state=state, conv_prev=conv, chunk=chunk,
    )
    return h + y, st


# --------------------------------------------------------------------------
# parameter init for the whole model
# --------------------------------------------------------------------------


def _stacked(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {
        "embed": embed_init(ks[0], (cfg.vocab, cfg.d_model), dtype),
        "final_norm": init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = embed_init(ks[1], (cfg.vocab, cfg.d_model), dtype)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        p["layers"] = _stacked(lambda k: init_dense_block(k, cfg, dtype), ks[2], cfg.n_layers)
    elif fam == "moe":
        every = cfg.moe_every
        n_macro = cfg.n_layers // every
        if every == 1:
            p["layers"] = _stacked(lambda k: init_moe_block(k, cfg, dtype), ks[2], n_macro)
        else:
            # macro layer = (every-1) dense + 1 moe
            p["layers"] = _stacked(
                lambda k: {
                    "dense": _stacked(
                        lambda kk: init_dense_block(kk, cfg, dtype), k, every - 1
                    ),
                    "moe": init_moe_block(jax.random.fold_in(k, 7), cfg, dtype),
                },
                ks[2],
                n_macro,
            )
    elif fam == "ssm":
        p["layers"] = _stacked(lambda k: init_mamba_block(k, cfg, dtype), ks[2], cfg.n_layers)
    elif fam == "hybrid":
        every = cfg.hybrid_attn_every
        n_macro = cfg.n_layers // every
        tail = cfg.n_layers % every
        p["layers"] = _stacked(
            lambda k: _stacked(lambda kk: init_mamba_block(kk, cfg, dtype), k, every),
            ks[2],
            n_macro,
        )
        if tail:
            p["tail"] = _stacked(lambda k: init_mamba_block(k, cfg, dtype), ks[3], tail)
        p["shared_attn"] = {
            "ln": init_rmsnorm(cfg.d_model, dtype),
            "attn": attn.init_attn(ks[4], attn_dims(cfg), dtype),
            "ln2": init_rmsnorm(cfg.d_model, dtype),
            "mlp": init_mlp(ks[5], cfg.d_model, cfg.d_ff, dtype),
        }
    elif fam == "audio":  # encoder-decoder
        p["enc_layers"] = _stacked(
            lambda k: {
                "ln1": init_rmsnorm(cfg.d_model, dtype),
                "attn": attn.init_attn(k, attn_dims(cfg, causal=False), dtype),
                "ln2": init_rmsnorm(cfg.d_model, dtype),
                "mlp": init_mlp(jax.random.fold_in(k, 3), cfg.d_model, cfg.d_ff, dtype),
            },
            ks[2],
            cfg.enc_layers,
        )
        p["enc_norm"] = init_rmsnorm(cfg.d_model, dtype)
        p["layers"] = _stacked(
            lambda k: {
                "ln1": init_rmsnorm(cfg.d_model, dtype),
                "attn": attn.init_attn(k, attn_dims(cfg), dtype),
                "lnx": init_rmsnorm(cfg.d_model, dtype),
                "xattn": attn.init_attn(jax.random.fold_in(k, 5), attn_dims(cfg, causal=False), dtype),
                "ln2": init_rmsnorm(cfg.d_model, dtype),
                "mlp": init_mlp(jax.random.fold_in(k, 3), cfg.d_model, cfg.d_ff, dtype),
            },
            ks[3],
            cfg.dec_layers,
        )
    else:
        raise ValueError(fam)
    return p


# --------------------------------------------------------------------------
# forward (training / scoring): tokens -> loss
# --------------------------------------------------------------------------


def _scan_layers(stack_params, body, h, remat: bool = True):
    fn = jax.checkpoint(body) if remat else body

    def step(carry, p_l):
        return fn(carry, p_l), None

    h, _ = jax.lax.scan(step, h, stack_params)
    return h


def _decoder_stack(cfg: ArchConfig, params, h, *, q_chunk=512, ssm_chunk=256, remat=True):
    fam = cfg.family
    if fam in ("dense", "vlm"):
        h = _scan_layers(
            params["layers"], lambda hh, p: dense_block(p, cfg, hh, q_chunk=q_chunk), h, remat
        )
    elif fam == "moe":
        if cfg.moe_every == 1:
            h = _scan_layers(
                params["layers"], lambda hh, p: moe_block(p, cfg, hh, q_chunk=q_chunk), h, remat
            )
        else:

            def macro(hh, p):
                def inner(h2, pd):
                    return dense_block(pd, cfg, h2, q_chunk=q_chunk), None

                hh, _ = jax.lax.scan(inner, hh, p["dense"])
                return moe_block(p["moe"], cfg, hh, q_chunk=q_chunk)

            h = _scan_layers(params["layers"], macro, h, remat)
    elif fam == "ssm":

        def body(hh, p):
            out, _ = mamba_block(p, cfg, hh, chunk=ssm_chunk)
            return out

        h = _scan_layers(params["layers"], body, h, remat)
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def macro(hh, p):
            def inner(h2, pm):
                out, _ = mamba_block(pm, cfg, h2, chunk=ssm_chunk)
                return out, None

            hh, _ = jax.lax.scan(inner, hh, p)
            hh = hh + attn.attn_train(
                shared["attn"], attn_dims(cfg), rmsnorm(hh, shared["ln"], cfg.norm_eps),
                q_chunk=q_chunk,
            )
            hh = hh + mlp_ffn(shared["mlp"], rmsnorm(hh, shared["ln2"], cfg.norm_eps))
            return hh

        h = _scan_layers(params["layers"], macro, h, remat)
        if "tail" in params:

            def tail_body(hh, p):
                out, _ = mamba_block(p, cfg, hh, chunk=ssm_chunk)
                return out

            h = _scan_layers(params["tail"], tail_body, h, remat)
    else:
        raise ValueError(fam)
    return h


def forward_loss(
    cfg: ArchConfig,
    params,
    batch: dict,
    *,
    q_chunk: int = 512,
    ssm_chunk: int = 256,
    remat: bool = True,
) -> jax.Array:
    """batch: tokens [B, S_txt] (+ prefix_embeds / enc_embeds per family).
    Returns mean next-token NLL (plus MoE aux loss where applicable)."""
    tokens = batch["tokens"]
    B, S_txt = tokens.shape
    tokens = shard(tokens, BATCH, None)

    if cfg.is_enc_dec:
        enc_h = shard(batch["enc_embeds"].astype(params["embed"].dtype), BATCH, None, None)

        def enc_body(hh, p):
            hh = hh + attn.attn_train(
                p["attn"], attn_dims(cfg, causal=False), rmsnorm(hh, p["ln1"], cfg.norm_eps),
                q_chunk=q_chunk,
            )
            return hh + mlp_ffn(p["mlp"], rmsnorm(hh, p["ln2"], cfg.norm_eps))

        enc_h = _scan_layers(params["enc_layers"], enc_body, enc_h, remat)
        enc_h = rmsnorm(enc_h, params["enc_norm"], cfg.norm_eps)

        h = embed(tokens, params["embed"])
        h = shard(h, BATCH, None, None)
        xdims = attn_dims(cfg, causal=False)

        def dec_body(hh, p):
            hh = hh + attn.attn_train(
                p["attn"], attn_dims(cfg), rmsnorm(hh, p["ln1"], cfg.norm_eps), q_chunk=q_chunk
            )
            kv = attn.cross_kv(p["xattn"], xdims, enc_h)
            hh = hh + attn.attn_cross(
                p["xattn"], xdims, rmsnorm(hh, p["lnx"], cfg.norm_eps), kv, q_chunk=q_chunk
            )
            return hh + mlp_ffn(p["mlp"], rmsnorm(hh, p["ln2"], cfg.norm_eps))

        h = _scan_layers(params["layers"], dec_body, h, remat)
        loss_tokens, loss_mask = tokens, None
    else:
        h = embed(tokens, params["embed"])
        if cfg.frontend == "vision" and "prefix_embeds" in batch:
            pre = batch["prefix_embeds"].astype(h.dtype)  # [B, P, D]
            h = jnp.concatenate([pre, h], axis=1)
        h = shard(h, BATCH, None, None)
        h = _decoder_stack(cfg, params, h, q_chunk=q_chunk, ssm_chunk=ssm_chunk, remat=remat)
        if cfg.frontend == "vision" and "prefix_embeds" in batch:
            h = h[:, batch["prefix_embeds"].shape[1] :]
        loss_tokens, loss_mask = tokens, None

    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    # next-token: predict t+1 from t
    labels = jnp.concatenate([loss_tokens[:, 1:], loss_tokens[:, -1:]], axis=1)
    mask = jnp.ones_like(labels, jnp.float32).at[:, -1].set(0.0)
    return unembed_loss(h, table, labels, mask)


# --------------------------------------------------------------------------
# prefill / decode (serving)
# --------------------------------------------------------------------------


def _cache_spec(cfg: ArchConfig, B: int, T: int, dtype):
    """Initial cache pytree (stacked over scan dim like the params)."""
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        n_stack = cfg.n_layers if fam != "moe" else cfg.n_layers // cfg.moe_every
        d = attn_dims(cfg)

        def one(_):
            c = attn.init_cache(d, B, T, dtype)
            if fam == "moe" and cfg.moe_every > 1:
                return {
                    "dense": jax.tree.map(
                        lambda x: jnp.broadcast_to(x, (cfg.moe_every - 1, *x.shape)),
                        attn.init_cache(d, B, T, dtype),
                    ),
                    "moe": c,
                }
            return c

        caches = one(None)
        return jax.tree.map(lambda x: jnp.zeros((n_stack, *x.shape), x.dtype), caches)
    if fam == "ssm":
        h, conv = ssm_lib.init_ssm_state(ssm_dims(cfg), B, dtype)
        return {
            "ssm": jnp.zeros((cfg.n_layers, *h.shape), h.dtype),
            "conv": jnp.zeros((cfg.n_layers, *conv.shape), conv.dtype),
        }
    if fam == "hybrid":
        every = cfg.hybrid_attn_every
        n_macro = cfg.n_layers // every
        tail = cfg.n_layers % every
        hh, conv = ssm_lib.init_ssm_state(ssm_dims(cfg), B, dtype)
        d = attn_dims(cfg, sliding=cfg.long_context_window if T > 65536 else cfg.sliding_window)
        kv_T = min(T, cfg.long_context_window) if T > 65536 else T
        out = {
            "ssm": jnp.zeros((n_macro, every, *hh.shape), hh.dtype),
            "conv": jnp.zeros((n_macro, every, *conv.shape), conv.dtype),
            "kv": jax.tree.map(
                lambda x: jnp.zeros((n_macro, *x.shape), x.dtype),
                attn.init_cache(d, B, kv_T, dtype),
            ),
        }
        if tail:
            out["tail_ssm"] = jnp.zeros((tail, *hh.shape), hh.dtype)
            out["tail_conv"] = jnp.zeros((tail, *conv.shape), conv.dtype)
        return out
    if fam == "audio":
        d = attn_dims(cfg)
        self_kv = jax.tree.map(
            lambda x: jnp.zeros((cfg.dec_layers, *x.shape), x.dtype),
            attn.init_cache(d, B, T, dtype),
        )
        return {"self": self_kv}  # cross-KV computed at prefill, carried separately
    raise ValueError(fam)


def decode_step(
    cfg: ArchConfig, params, cache, token: jax.Array, position, *, enc_kv=None
):
    """One-token serve step.  token: [B, 1] int32; returns (logits, cache)."""
    dtype = params["embed"].dtype
    h = embed(token, params["embed"])
    h = shard(h, BATCH, None, None)
    fam = cfg.family

    if fam in ("dense", "vlm"):

        def body(hh, xs):
            p, c = xs
            a, nc = attn.attn_decode(
                p["attn"], attn_dims(cfg), rmsnorm(hh, p["ln1"], cfg.norm_eps), c, position
            )
            hh = hh + a
            hh = hh + mlp_ffn(p["mlp"], rmsnorm(hh, p["ln2"], cfg.norm_eps))
            return hh, nc

        h, new_cache = jax.lax.scan(body, h, (params["layers"], cache))
    elif fam == "moe":
        if cfg.moe_every == 1:

            def body(hh, xs):
                p, c = xs
                a, nc = attn.attn_decode(
                    p["attn"], attn_dims(cfg), rmsnorm(hh, p["ln1"], cfg.norm_eps), c, position
                )
                hh = hh + a
                hh = hh + moe_lib.moe_ffn(
                    p["moe"], moe_dims(cfg), rmsnorm(hh, p["ln2"], cfg.norm_eps)
                )
                return hh, nc

            h, new_cache = jax.lax.scan(body, h, (params["layers"], cache))
        else:

            def macro(hh, xs):
                p, c = xs

                def inner(h2, xs2):
                    pd, cd = xs2
                    a, nc = attn.attn_decode(
                        pd["attn"], attn_dims(cfg), rmsnorm(h2, pd["ln1"], cfg.norm_eps), cd, position
                    )
                    h2 = h2 + a
                    h2 = h2 + mlp_ffn(pd["mlp"], rmsnorm(h2, pd["ln2"], cfg.norm_eps))
                    return h2, nc

                hh, ncd = jax.lax.scan(inner, hh, (p["dense"], c["dense"]))
                a, ncm = attn.attn_decode(
                    p["moe"]["attn"], attn_dims(cfg), rmsnorm(hh, p["moe"]["ln1"], cfg.norm_eps),
                    c["moe"], position,
                )
                hh = hh + a
                hh = hh + moe_lib.moe_ffn(
                    p["moe"]["moe"], moe_dims(cfg), rmsnorm(hh, p["moe"]["ln2"], cfg.norm_eps)
                )
                return hh, {"dense": ncd, "moe": ncm}

            h, new_cache = jax.lax.scan(macro, h, (params["layers"], cache))
    elif fam == "ssm":

        def body(hh, xs):
            p, (st, cv) = xs
            out, (nst, ncv) = mamba_block(p, cfg, hh, state=st, conv=cv, chunk=1)
            return out, (nst, ncv)

        h, (ns, nc) = jax.lax.scan(body, h, (params["layers"], (cache["ssm"], cache["conv"])))
        new_cache = {"ssm": ns, "conv": nc}
    elif fam == "hybrid":
        shared = params["shared_attn"]
        # ring cache == window at 500k: the ring itself enforces the sliding
        # window, so the attention dims carry sliding=0 (see attn_decode).
        d = attn_dims(cfg, sliding=0)

        def macro(hh, xs):
            p, (st, cv, kv) = xs

            def inner(h2, xs2):
                pm, (s2, c2) = xs2
                out, (ns2, nc2) = mamba_block(pm, cfg, h2, state=s2, conv=c2, chunk=1)
                return out, (ns2, nc2)

            hh, (nst, ncv) = jax.lax.scan(inner, hh, (p, (st, cv)))
            a, nkv = attn.attn_decode(
                shared["attn"], d, rmsnorm(hh, shared["ln"], cfg.norm_eps), kv, position,
            )
            hh = hh + a
            hh = hh + mlp_ffn(shared["mlp"], rmsnorm(hh, shared["ln2"], cfg.norm_eps))
            return hh, (nst, ncv, nkv)

        h, (ns, nc, nkv) = jax.lax.scan(
            macro, h, (params["layers"], (cache["ssm"], cache["conv"], cache["kv"]))
        )
        new_cache = dict(cache, ssm=ns, conv=nc, kv=nkv)
        if "tail" in params:

            def tail_body(hh, xs):
                p, (st, cv) = xs
                out, (nst, ncv) = mamba_block(p, cfg, hh, state=st, conv=cv, chunk=1)
                return out, (nst, ncv)

            h, (ts, tc) = jax.lax.scan(
                tail_body, h, (params["tail"], (cache["tail_ssm"], cache["tail_conv"]))
            )
            new_cache["tail_ssm"], new_cache["tail_conv"] = ts, tc
    elif fam == "audio":
        xdims = attn_dims(cfg, causal=False)

        def body(hh, xs):
            p, c, ekv = xs
            a, nc = attn.attn_decode(
                p["attn"], attn_dims(cfg), rmsnorm(hh, p["ln1"], cfg.norm_eps), c, position
            )
            hh = hh + a
            hh = hh + attn.attn_cross(
                p["xattn"], xdims, rmsnorm(hh, p["lnx"], cfg.norm_eps), ekv, q_chunk=1
            )
            hh = hh + mlp_ffn(p["mlp"], rmsnorm(hh, p["ln2"], cfg.norm_eps))
            return hh, nc

        h, nself = jax.lax.scan(body, h, (params["layers"], cache["self"], enc_kv))
        new_cache = {"self": nself}
    else:
        raise ValueError(fam)

    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return unembed_logits(h, table), new_cache


def prefill(
    cfg: ArchConfig, params, tokens, *, prefix_embeds=None,
    q_chunk: int = 512, ssm_chunk: int = 256,
):
    """Prefill over a prompt: returns (last-position logits, cache).

    ``prefix_embeds`` [B, P, D]: stub modality frontend output (vlm) prepended
    before the token embeddings; the KV cache then covers P + S positions.
    """
    B, S = tokens.shape
    h = embed(shard(tokens, BATCH, None), params["embed"])
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    h = shard(h, BATCH, None, None)
    fam = cfg.family

    if fam in ("dense", "vlm"):

        def body(hh, p):
            a, kv = attn.attn_prefill(
                p["attn"], attn_dims(cfg), rmsnorm(hh, p["ln1"], cfg.norm_eps), q_chunk=q_chunk
            )
            hh = hh + a
            hh = hh + mlp_ffn(p["mlp"], rmsnorm(hh, p["ln2"], cfg.norm_eps))
            return hh, kv

        h, caches = jax.lax.scan(body, h, params["layers"])
        cache = caches
    elif fam == "moe":
        if cfg.moe_every == 1:

            def body(hh, p):
                a, kv = attn.attn_prefill(
                    p["attn"], attn_dims(cfg), rmsnorm(hh, p["ln1"], cfg.norm_eps), q_chunk=q_chunk
                )
                hh = hh + a
                hh = hh + moe_lib.moe_ffn(
                    p["moe"], moe_dims(cfg), rmsnorm(hh, p["ln2"], cfg.norm_eps)
                )
                return hh, kv

            h, cache = jax.lax.scan(body, h, params["layers"])
        else:

            def macro(hh, p):
                def inner(h2, pd):
                    a, kv = attn.attn_prefill(
                        pd["attn"], attn_dims(cfg), rmsnorm(h2, pd["ln1"], cfg.norm_eps),
                        q_chunk=q_chunk,
                    )
                    h2 = h2 + a
                    h2 = h2 + mlp_ffn(pd["mlp"], rmsnorm(h2, pd["ln2"], cfg.norm_eps))
                    return h2, kv

                hh, kvd = jax.lax.scan(inner, hh, p["dense"])
                a, kvm = attn.attn_prefill(
                    p["moe"]["attn"], attn_dims(cfg), rmsnorm(hh, p["moe"]["ln1"], cfg.norm_eps),
                    q_chunk=q_chunk,
                )
                hh = hh + a
                hh = hh + moe_lib.moe_ffn(
                    p["moe"]["moe"], moe_dims(cfg), rmsnorm(hh, p["moe"]["ln2"], cfg.norm_eps)
                )
                return hh, {"dense": kvd, "moe": kvm}

            h, cache = jax.lax.scan(macro, h, params["layers"])
    elif fam == "ssm":

        def body(hh, p):
            out, (st, cv) = mamba_block(p, cfg, hh, chunk=ssm_chunk)
            return out, (st, cv)

        h, (st, cv) = jax.lax.scan(body, h, params["layers"])
        cache = {"ssm": st, "conv": cv}
    elif fam == "hybrid":
        shared = params["shared_attn"]

        def macro(hh, p):
            def inner(h2, pm):
                out, (s2, c2) = mamba_block(pm, cfg, h2, chunk=ssm_chunk)
                return out, (s2, c2)

            hh, (st, cv) = jax.lax.scan(inner, hh, p)
            a, kv = attn.attn_prefill(
                shared["attn"], attn_dims(cfg), rmsnorm(hh, shared["ln"], cfg.norm_eps),
                q_chunk=q_chunk,
            )
            hh = hh + a
            hh = hh + mlp_ffn(shared["mlp"], rmsnorm(hh, shared["ln2"], cfg.norm_eps))
            return hh, (st, cv, kv)

        h, (st, cv, kv) = jax.lax.scan(macro, h, params["layers"])
        cache = {"ssm": st, "conv": cv, "kv": kv}
        if "tail" in params:

            def tail_body(hh, p):
                out, (s2, c2) = mamba_block(p, cfg, hh, chunk=ssm_chunk)
                return out, (s2, c2)

            h, (ts, tc) = jax.lax.scan(tail_body, h, params["tail"])
            cache["tail_ssm"], cache["tail_conv"] = ts, tc
    elif fam == "audio":
        raise ValueError("audio prefill goes through prefill_encdec")
    else:
        raise ValueError(fam)

    h = rmsnorm(h[:, -1:], params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return unembed_logits(h, table), cache


def prefill_encdec(cfg: ArchConfig, params, enc_embeds, dec_tokens, *, q_chunk=512):
    """Encoder pass + decoder prefill; returns (logits, self-cache, cross-KV)."""
    xdims = attn_dims(cfg, causal=False)
    enc_h = enc_embeds.astype(params["embed"].dtype)
    enc_h = shard(enc_h, BATCH, None, None)

    def enc_body(hh, p):
        hh = hh + attn.attn_train(
            p["attn"], attn_dims(cfg, causal=False), rmsnorm(hh, p["ln1"], cfg.norm_eps),
            q_chunk=q_chunk,
        )
        return hh + mlp_ffn(p["mlp"], rmsnorm(hh, p["ln2"], cfg.norm_eps)), None

    enc_h, _ = jax.lax.scan(enc_body, enc_h, params["enc_layers"])
    enc_h = rmsnorm(enc_h, params["enc_norm"], cfg.norm_eps)

    h = embed(dec_tokens, params["embed"])

    def dec_body(hh, p):
        a, kv = attn.attn_prefill(
            p["attn"], attn_dims(cfg), rmsnorm(hh, p["ln1"], cfg.norm_eps), q_chunk=q_chunk
        )
        hh = hh + a
        ekv = attn.cross_kv(p["xattn"], xdims, enc_h)
        hh = hh + attn.attn_cross(
            p["xattn"], xdims, rmsnorm(hh, p["lnx"], cfg.norm_eps), ekv, q_chunk=q_chunk
        )
        hh = hh + mlp_ffn(p["mlp"], rmsnorm(hh, p["ln2"], cfg.norm_eps))
        return hh, (kv, ekv)

    h, (self_kv, enc_kv) = jax.lax.scan(dec_body, h, params["layers"])
    h = rmsnorm(h[:, -1:], params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return unembed_logits(h, table), {"self": self_kv}, enc_kv
