from repro.models.lm import (
    decode_step,
    forward_loss,
    init_params,
    prefill,
    prefill_encdec,
    _cache_spec as cache_spec,
)
from repro.models.common import shard

__all__ = [
    "decode_step",
    "forward_loss",
    "init_params",
    "prefill",
    "prefill_encdec",
    "cache_spec",
    "shard",
]
