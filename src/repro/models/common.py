"""Shared model building blocks: norms, RoPE, embeddings, init, sharding.

Parameters are plain nested dicts of jax.Arrays.  Layers of a homogeneous
stack are *stacked* along a leading ``[L]`` axis and consumed by
``jax.lax.scan`` — this keeps HLO size O(1) in depth, which is what makes
88-to-94-layer configs compile quickly in the 512-device dry-run.

Sharding: model code annotates activations with
``jax.lax.with_sharding_constraint`` through :func:`shard`; outside a mesh
context the helper is a no-op, so smoke tests run unchanged on one CPU
device.  Parameter shardings are assigned by ``launch/shardings.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# --------------------------------------------------------------------------
# sharding helper: constraint-if-mesh
# --------------------------------------------------------------------------


def _cur_mesh():
    get_abstract_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract_mesh is not None:  # jax >= 0.5; older falls through to legacy
        m = get_abstract_mesh()
        if m is not None and not m.empty:
            return m
    try:  # legacy `with mesh:` context (what launch/dryrun.py uses)
        from jax._src import mesh as mesh_lib

        pm = mesh_lib.thread_resources.env.physical_mesh
        if pm is not None and not pm.empty:
            return pm
    except Exception:
        pass
    return None


def shard(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint(x, P(*spec)) under a mesh; no-op otherwise.

    Two cleanups make one model code path serve every (arch × mesh) cell:
    * axis names absent from the current mesh are dropped (single-pod vs
      multi-pod), and
    * axes that do not evenly divide their dim are dropped (e.g. 24 heads on
      a 16-way model axis fall back to replication instead of GSPMD padding,
      which was measured to trigger full-batch all-gathers — EXPERIMENTS.md
      §Perf iteration 1).
    """
    mesh = _cur_mesh()
    if mesh is None:
        return x
    try:
        sizes = dict(mesh.shape)  # Mesh.shape is an OrderedDict name->size
    except Exception:
        sizes = dict(zip(mesh.axis_names, mesh.shape))

    # the canonical batch tuple routes through the strategy flag (ZeRO-3
    # folds `model` into the batch axes)
    from repro.models import flags

    spec = tuple(
        flags.batch_axes() if (isinstance(e, tuple) and set(e) == {"pod", "data"}) else e
        for e in spec
    )

    used: set = set()

    def keep(e, dim):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept, prod = [], 1
            for a in e:
                if a in sizes and a not in used:
                    kept.append(a)
                    prod *= sizes[a]
            if kept and dim % prod == 0:
                used.update(kept)
                return tuple(kept)
            return None
        if e in sizes and e not in used and dim % sizes[e] == 0:
            used.add(e)
            return e
        return None

    cleaned = [keep(e, d) for e, d in zip(spec, x.shape)]
    return jax.lax.with_sharding_constraint(x, P(*cleaned))


BATCH = ("pod", "data")  # batch shards over pod+data axes when present


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------


def dense_init(key, shape, dtype, fan_in: int | None = None):
    fan = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.maximum(fan, 1)).astype(jnp.float32)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """RMSNorm with fp32 *reduction* but a bf16 data path.

    Upcasting the whole tensor (the textbook form) made XLA hoist the
    f32 convert across the tensor-parallel all-reduces, doubling every
    activation collective (EXPERIMENTS.md §Perf iteration A1).  Keeping x in
    its own dtype and broadcasting the f32 rsqrt keeps the TP psums bf16;
    only the variance reduction runs in f32.
    """
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + scale).astype(x.dtype)


def init_rmsnorm(d: int, dtype) -> jax.Array:
    return jnp.zeros((d,), dtype)  # stored as (scale - 1)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    sin = jnp.sin(ang)[..., None, :]  # [..., S, 1, hd/2]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# embedding / unembedding with chunked softmax-xent (vocab can be 256k)
# --------------------------------------------------------------------------


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed_loss(
    h: jax.Array,  # [B, S, D]
    table: jax.Array,  # [V, D]
    labels: jax.Array,  # [B, S]
    mask: jax.Array | None = None,  # [B, S]
    chunk: int = 1024,
) -> jax.Array:
    """Mean next-token cross-entropy, computed in sequence chunks so the
    [B, chunk, V] logits block (not [B, S, V]) is the live working set."""
    B, S, D = h.shape
    n_chunks = max(1, S // chunk)
    chunk = S // n_chunks
    assert n_chunks * chunk == S, f"seq {S} not divisible into {n_chunks} chunks"
    h_c = h.reshape(B, n_chunks, chunk, D).transpose(1, 0, 2, 3)
    y_c = labels.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
    m_c = (
        mask.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
        if mask is not None
        else jnp.ones((n_chunks, B, chunk), jnp.float32)
    )

    def body(carry, xs):
        hb, yb, mb = xs
        logits = jnp.einsum("bsd,vd->bsv", hb, table).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yb[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mb.astype(jnp.float32)
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(mb)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (h_c, y_c, m_c))
    return tot / jnp.maximum(cnt, 1.0)


def unembed_logits(h: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.einsum("...d,vd->...v", h, table)
