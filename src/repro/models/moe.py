"""Mixture-of-Experts FFN: token-choice top-k routing with sort-based
dispatch into capacity-bounded expert buffers.

Why sort-based (DESIGN.md §3, hardware adaptation): the classic one-hot
dispatch einsum materializes a [tokens, experts, capacity] tensor — at
qwen3-235b scale (65k local tokens × 128 experts × 5k capacity) that is
~10^13 elements.  Instead we:

  1. route: top-k experts per token (gates renormalized),
  2. sort (expert, token) pairs by expert id (one lax.sort),
  3. position-in-expert via a cumsum over the sorted run,
  4. scatter tokens into an [E, C, D] buffer (overflow = dropped token,
     standard capacity-factor semantics),
  5. batched per-expert FFN einsum [E,C,D]x[E,D,F] — MXU-dense,
  6. gather back and combine with gates.

The [E, C, D] buffer is the object EP shards over the ``model`` axis: tokens
are replicated across ``model`` (megatron-style activations), each model
shard scatters/computes only its local experts, and the combine's psum over
``model`` is the same all-reduce TP already pays.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import compat

from repro.models.common import dense_init, shard


@dataclasses.dataclass(frozen=True)
class MoEDims:
    d_model: int
    n_experts: int
    top_k: int
    d_ff: int  # per-expert hidden
    capacity_factor: float = 1.25
    shared_expert: bool = False
    shared_d_ff: int = 0  # defaults to d_ff


def init_moe(key, dims: MoEDims, dtype) -> dict:
    ks = jax.random.split(key, 5)
    D, E, F = dims.d_model, dims.n_experts, dims.d_ff
    p = {
        "router": dense_init(ks[0], (D, E), jnp.float32),  # router in f32
        "w_gate": dense_init(ks[1], (E, D, F), dtype),
        "w_up": dense_init(ks[2], (E, D, F), dtype),
        "w_down": dense_init(ks[3], (E, F, D), dtype, fan_in=F),
    }
    if dims.shared_expert:
        from repro.models.mlp import init_mlp

        p["shared"] = init_mlp(ks[4], D, dims.shared_d_ff or F, dtype)
    return p


def capacity(dims: MoEDims, n_tokens: int) -> int:
    c = int(n_tokens * dims.top_k * dims.capacity_factor / dims.n_experts)
    return max(8, ((c + 7) // 8) * 8)  # 8-aligned for TPU tiling


def _dp_groups() -> int:
    from repro.models.common import _cur_mesh

    mesh = _cur_mesh()
    if mesh is None:
        return 1
    sizes = dict(mesh.shape)
    g = 1
    for a in ("pod", "data"):
        g *= sizes.get(a, 1)
    return g


def _moe_mesh():
    """Physical mesh with a model axis, if one is active (shard_map needs it)."""
    from repro.models.common import _cur_mesh

    mesh = _cur_mesh()
    if mesh is None or "model" not in mesh.axis_names or not hasattr(mesh, "devices"):
        return None
    return mesh


def _dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _dispatch_gather(xg_pad: jax.Array, tok_of_slot: jax.Array) -> jax.Array:
    """buf[g, s] = xg_pad[g, tok_of_slot[g, s]] with explicit locality.

    xg_pad: [G, Tl+1, D] (group-sharded, replicated over model);
    tok_of_slot: [G, E*C] (group + model sharded).  Inside shard_map every
    device gathers its local slots from its local group copy — no comm.
    """
    mesh = _moe_mesh()
    if mesh is None:
        return jnp.take_along_axis(xg_pad, tok_of_slot[..., None], axis=1)
    from jax.sharding import PartitionSpec as P

    dp = _dp_axes(mesh)

    def body(xg_l, tok_l):
        gl = tok_l.shape[0]
        idx = jnp.arange(gl)[:, None]
        return xg_l[idx, tok_l]  # [g_loc, slots_loc, D]

    return compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(dp, None, None), P(dp, "model")),
        out_specs=P(dp, "model", None),
    )(xg_pad, tok_of_slot)


def _combine_scatter(y_flat: jax.Array, tok_of_slot: jax.Array, Tl: int) -> jax.Array:
    """out[g, t] = sum over slots s with tok[g,s]==t of y_flat[g, s].

    Inside shard_map: local scatter-add into the group accumulator, then one
    bf16 psum over `model` — the minimal EP combine.
    """
    mesh = _moe_mesh()
    G, _, D = y_flat.shape
    if mesh is None:
        gi = jnp.arange(G, dtype=jnp.int32)[:, None]
        return jnp.zeros((G, Tl + 1, D), y_flat.dtype).at[gi, tok_of_slot].add(y_flat)
    from jax.sharding import PartitionSpec as P

    dp = _dp_axes(mesh)

    def body(y_l, tok_l):
        gl = tok_l.shape[0]
        idx = jnp.arange(gl)[:, None]
        out = jnp.zeros((gl, Tl + 1, D), y_l.dtype).at[idx, tok_l].add(y_l)
        return jax.lax.psum(out, "model")

    return compat.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(dp, "model", None), P(dp, "model")),
        out_specs=P(dp, None, None),
    )(y_flat, tok_of_slot)


def moe_ffn(params, dims: MoEDims, x: jax.Array) -> jax.Array:
    """x: [B, S, D] -> [B, S, D].

    Grouped (GShard-style) dispatch: tokens are split into G groups aligned
    with the (pod, data) batch shards, so routing, the [G, E, C, D] expert
    buffer, and the combine all stay group-local.  Crucially, dispatch and
    combine are *slot-side gathers/scatters* — `buf[slot] = x[token_of_slot]`
    — so no [T*K, D] pair tensor ever materializes (the naive combine
    all-reduced 137 GB per layer at qwen3 scale; EXPERIMENTS.md §Perf).
    """
    B, S, D = x.shape
    E, K = dims.n_experts, dims.top_k
    T = B * S
    if S == 1:
        return _moe_ffn_decode(params, dims, x)
    G = _dp_groups()
    if T % G != 0 or B % G != 0:
        G = 1
    Tl = T // G
    C = capacity(dims, Tl)

    xg = x.reshape(G, Tl, D)
    xg = shard(xg, ("pod", "data"), None, None)

    # 1. routing (f32)
    logits = xg.astype(jnp.float32) @ params["router"]  # [G, Tl, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)  # [G, Tl, K]
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # 2. sort (expert, token) pairs by expert, per group.  Integer keys only
    # (lax.sort JVP is unusable in this jax/jaxlib pairing); differentiable
    # gates follow via the permutation.
    flat_e = gate_idx.reshape(G, Tl * K).astype(jnp.int32)
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tl, dtype=jnp.int32), K)[None], (G, Tl * K)
    )
    perm0 = jnp.broadcast_to(jnp.arange(Tl * K, dtype=jnp.int32)[None], (G, Tl * K))
    se, st, perm = jax.lax.sort((flat_e, flat_t, perm0), dimension=1, num_keys=2)
    sg = jnp.take_along_axis(gate_vals.reshape(G, Tl * K), perm, axis=1)

    # 3. position within expert run
    pos = jnp.arange(Tl * K, dtype=jnp.int32)[None]
    run_start = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(E, dtype=jnp.int32), side="left")
    )(se)  # [G, E]
    gi = jnp.arange(G, dtype=jnp.int32)[:, None]
    pos_in_e = pos - run_start[gi, se]
    keep = pos_in_e < C
    slot = jnp.where(keep, se * C + pos_in_e, E * C)  # E*C = trash slot

    # 4. slot-side maps: token and gate per buffer slot (tiny int/f32 arrays)
    tok_of_slot = jnp.full((G, E * C + 1), Tl, jnp.int32).at[gi, slot].set(st)
    gate_of_slot = jnp.zeros((G, E * C + 1), jnp.float32).at[gi, slot].set(sg)
    tok_of_slot = tok_of_slot[:, : E * C]
    gate_of_slot = gate_of_slot[:, : E * C]

    # 5. dispatch = one gather (pad row Tl reads zeros).  Under a mesh this
    # runs in shard_map: xg is naturally replicated over `model`, each model
    # shard gathers its own expert slots — zero communication.  GSPMD's
    # auto-partitioned gather instead replicated the full [G, Tl, D] tensor
    # (17 GB f32/layer measured at qwen3 scale).
    xg_pad = jnp.concatenate([xg, jnp.zeros((G, 1, D), x.dtype)], axis=1)
    buf = _dispatch_gather(xg_pad, tok_of_slot)  # [G, E*C, D]
    buf = buf.reshape(G, E, C, D)
    buf = shard(buf, ("pod", "data"), "model", None, None)  # EP over model

    # 6. batched expert FFN (SwiGLU)
    g_ = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"])
    u_ = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    h = jax.nn.silu(g_) * u_
    h = shard(h, ("pod", "data"), "model", None, None)
    y = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    y = shard(y, ("pod", "data"), "model", None, None)

    # 7. combine = one gate-weighted scatter-add from the sharded buffer.
    # shard_map again: each device scatters its local expert slots into its
    # group's [Tl+1, D] accumulator, then one bf16 psum over `model` — the
    # minimal EP-combine collective.
    y_flat = (y.reshape(G, E * C, D) * gate_of_slot[..., None]).astype(x.dtype)
    out = _combine_scatter(y_flat, tok_of_slot, Tl)
    out = out[:, :Tl]
    out = shard(out, ("pod", "data"), None, None)

    if dims.shared_expert:
        from repro.models.mlp import mlp_ffn

        out = out + mlp_ffn(params["shared"], xg)

    return out.reshape(B, S, D)


def _moe_ffn_decode(params, dims: MoEDims, x: jax.Array) -> jax.Array:
    """Decode-mode MoE (S==1): single group, D-sharded residual convention.

    Buffers are token-count-sized (tiny), so plain gathers/scatters suffice;
    what matters is the expert einsum contracting D over `data` in place —
    the GSPMD default gathered 4.8 GB of expert weights per layer per token
    (EXPERIMENTS.md §Perf iteration B2).
    """
    B, S, D = x.shape
    E, K = dims.n_experts, dims.top_k
    T = B * S
    C = capacity(dims, T)
    xf = shard(x.reshape(T, D), None, ("data",))

    logits = xf.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    flat_e = gate_idx.reshape(-1).astype(jnp.int32)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    perm0 = jnp.arange(T * K, dtype=jnp.int32)
    se, st, perm = jax.lax.sort((flat_e, flat_t, perm0), dimension=0, num_keys=2)
    sg = gate_vals.reshape(-1)[perm]
    pos = jnp.arange(T * K, dtype=jnp.int32)
    run_start = jnp.searchsorted(se, jnp.arange(E, dtype=jnp.int32), side="left")
    pos_in_e = pos - run_start[se]
    keep = pos_in_e < C
    slot = jnp.where(keep, se * C + pos_in_e, E * C)

    tok_of_slot = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(st)[: E * C]
    gate_of_slot = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(sg)[: E * C]
    xf_pad = jnp.concatenate([xf, jnp.zeros((1, D), x.dtype)], axis=0)
    buf = xf_pad[tok_of_slot].reshape(E, C, D)
    buf = shard(buf, "model", None, ("data",))  # EP over model, D over data

    g_ = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    u_ = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = jax.nn.silu(g_) * u_
    h = shard(h, "model", None, None)
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    y = shard(y, "model", None, ("data",))

    y_flat = (y.reshape(E * C, D) * gate_of_slot[:, None]).astype(x.dtype)
    out = jnp.zeros((T + 1, D), x.dtype).at[tok_of_slot].add(y_flat)[:T]
    out = shard(out, None, ("data",))

    if dims.shared_expert:
        from repro.models.mlp import mlp_ffn

        out = out + mlp_ffn(params["shared"], xf[None]).reshape(T, D)

    return out.reshape(B, S, D)


def aux_load_balance_loss(params, dims: MoEDims, x: jax.Array) -> jax.Array:
    """Switch-style auxiliary loss: E * sum_e f_e * p_e."""
    B, S, D = x.shape
    xf = x.reshape(-1, D).astype(jnp.float32)
    probs = jax.nn.softmax(xf @ params["router"], axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    f = jnp.mean(jax.nn.one_hot(top1, dims.n_experts, dtype=jnp.float32), axis=0)
    p = jnp.mean(probs, axis=0)
    return dims.n_experts * jnp.sum(f * p)
