"""Attention: GQA with RoPE, chunked-causal training/prefill, KV-cache decode,
sliding windows, and cross-attention (enc-dec).

TPU adaptation notes (DESIGN.md §3): instead of materializing [S, S] score
matrices (4 GB/head at 32k), training/prefill scan over query chunks — the
live working set is one [B, H, cq, S_kv] block, VMEM-friendly and exactly the
structure a Pallas flash kernel would tile.  With a sliding window the KV
range per chunk is sliced, keeping FLOPs linear in S.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init, shard

NEG = -1e30


@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_model: int
    rope_theta: float = 10_000.0
    sliding_window: int = 0  # 0 = full
    causal: bool = True
    n_heads_padded: int = 0  # 0 -> n_heads (set via flags.pad_heads for TP)

    @property
    def hp(self) -> int:
        return self.n_heads_padded or self.n_heads

    def kv_expand_idx(self) -> jnp.ndarray:
        """Expanded-kv index map: q head h (h < H) uses kv group
        h // (H // K); pad heads map to group 0 (masked dead anyway)."""
        H, K = self.n_heads, self.n_kv_heads
        idx = jnp.arange(self.hp) // max(1, H // K)
        return jnp.minimum(idx, K - 1).astype(jnp.int32)

    def head_mask(self, dtype) -> jnp.ndarray | None:
        if self.hp == self.n_heads:
            return None
        return (jnp.arange(self.hp) < self.n_heads).astype(dtype)


def init_attn(key, dims: AttnDims, dtype) -> dict:
    ks = jax.random.split(key, 4)
    D, Hp, K, hd = dims.d_model, dims.hp, dims.n_kv_heads, dims.head_dim
    wq = dense_init(ks[0], (D, Hp * hd), dtype)
    wo = dense_init(ks[3], (Hp * hd, D), dtype, fan_in=dims.n_heads * hd)
    if Hp != dims.n_heads:
        # pad heads are exact zeros; output-masking keeps their grads zero,
        # so they remain zero forever — the math is the unpadded architecture
        col = (jnp.arange(Hp * hd) // hd) < dims.n_heads
        wq = wq * col[None, :].astype(wq.dtype)
        wo = wo * col[:, None].astype(wo.dtype)
    return {
        "wq": wq,
        "wk": dense_init(ks[1], (D, K * hd), dtype),
        "wv": dense_init(ks[2], (D, K * hd), dtype),
        "wo": wo,
    }


def _project_qkv(params, dims: AttnDims, x, positions):
    """Project + RoPE + expand KV heads to Hp (explicit GQA replication).

    The expansion makes the head axis uniformly Hp everywhere — padded to the
    TP degree when needed (flags.py) — so tensor parallelism is one clean
    shard of that axis.  The expanded kv costs [B,T,Hp/tp,hd] per device,
    which is what a megatron GQA shard holds anyway.
    """
    B, S, _ = x.shape
    K, hd, Hp = dims.n_kv_heads, dims.head_dim, dims.hp
    q = (x @ params["wq"]).reshape(B, S, Hp, hd)
    k = (x @ params["wk"]).reshape(B, S, K, hd)
    v = (x @ params["wv"]).reshape(B, S, K, hd)
    q = apply_rope(q, positions, dims.rope_theta)
    k = apply_rope(k, positions, dims.rope_theta)
    idx = dims.kv_expand_idx()
    k = jnp.take(k, idx, axis=2)
    v = jnp.take(v, idx, axis=2)
    q = shard(q, ("pod", "data"), None, "model", None)
    k = shard(k, ("pod", "data"), None, "model", None)
    v = shard(v, ("pod", "data"), None, "model", None)
    return q, k, v


def _mask_pad_heads(o, dims: AttnDims):
    """Zero the pad heads' outputs so wo's pad rows stay zero-gradient."""
    m = dims.head_mask(o.dtype)
    return o if m is None else o * m[None, None, :, None]


def _gqa_scores(q, k):  # q:[B,cq,H,hd] k:[B,T,H,hd] -> [B,H,cq,T]
    hd = q.shape[-1]
    s = jnp.einsum("bqhd,bthd->bhqt", q, k) / math.sqrt(hd)
    return s


def _gqa_out(p, v):  # p:[B,H,cq,T] v:[B,T,H,hd] -> [B,cq,H,hd]
    return jnp.einsum("bhqt,bthd->bqhd", p, v)


def attend_chunked(
    q: jax.Array,  # [B, S, H, hd] (RoPE applied)
    k: jax.Array,  # [B, T, K, hd]
    v: jax.Array,  # [B, T, K, hd]
    *,
    causal: bool,
    sliding_window: int = 0,
    q_chunk: int = 512,
    q_offset: int = 0,  # absolute position of q[0] (prefill continuation)
) -> jax.Array:
    """Scan over query chunks; each chunk sees only its legal KV range.

    Full-causal: chunk i attends kv[: (i+1)*cq + q_offset] — realized with a
    dynamic slice to ``hi`` rounded up to a chunk multiple, plus masking.
    Sliding window: kv range is a fixed-width slice around the chunk, so both
    memory AND FLOPs are O(S·w) instead of O(S²).
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    cq = min(q_chunk, S)
    if S % cq != 0:  # fall back to the largest chunk that divides S
        cq = math.gcd(S, cq)
    n = S // cq

    q_c = q.reshape(B, n, cq, H, hd).transpose(1, 0, 2, 3, 4)  # [n,B,cq,H,hd]

    if sliding_window and causal:
        w = sliding_window
        kv_span = min(T, ((w + cq + cq - 1) // cq) * cq)  # window + chunk, padded

        def body(_, xs):
            i, qb = xs
            q_abs0 = q_offset + i * cq
            lo = jnp.maximum(0, q_abs0 + cq - kv_span)
            kb = jax.lax.dynamic_slice_in_dim(k, lo, kv_span, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, lo, kv_span, axis=1)
            s = _gqa_scores(qb, kb)  # [B,H,cq,kv_span]
            qpos = q_abs0 + jnp.arange(cq)
            kpos = lo + jnp.arange(kv_span)
            ok = (kpos[None, :] <= qpos[:, None]) & (
                kpos[None, :] > qpos[:, None] - w
            )
            s = jnp.where(ok[None, None], s, NEG)
            p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
            return None, _gqa_out(p, vb)

        _, o = jax.lax.scan(body, None, (jnp.arange(n), q_c))
    else:

        def body(_, xs):
            i, qb = xs
            if causal:
                hi_static = T  # slice bound must be static inside scan; mask
                kb, vb = k, v
            else:
                kb, vb = k, v
            s = _gqa_scores(qb, kb)
            if causal:
                qpos = q_offset + i * cq + jnp.arange(cq)
                kpos = jnp.arange(T)
                ok = kpos[None, :] <= qpos[:, None]
                s = jnp.where(ok[None, None], s, NEG)
            p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
            return None, _gqa_out(p, vb)

        _, o = jax.lax.scan(body, None, (jnp.arange(n), q_c))

    return o.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


# --------------------------------------------------------------------------
# public ops
# --------------------------------------------------------------------------


def attn_train(params, dims: AttnDims, x, *, q_chunk: int = 512):
    """Self-attention over a full sequence (training / encoder)."""
    B, S, _ = x.shape
    pos = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(params, dims, x, pos)
    o = attend_chunked(
        q, k, v, causal=dims.causal, sliding_window=dims.sliding_window,
        q_chunk=q_chunk,
    )
    o = _mask_pad_heads(o, dims)
    o = o.reshape(B, S, dims.hp * dims.head_dim)
    return o @ params["wo"]


def attn_prefill(params, dims: AttnDims, x, *, q_chunk: int = 512):
    """Causal self-attention that also returns the KV cache."""
    B, S, _ = x.shape
    pos = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(params, dims, x, pos)
    o = attend_chunked(
        q, k, v, causal=True, sliding_window=dims.sliding_window, q_chunk=q_chunk
    )
    o = _mask_pad_heads(o, dims)
    o = o.reshape(B, S, dims.hp * dims.head_dim)
    return o @ params["wo"], {"k": k, "v": v}


def attn_decode(params, dims: AttnDims, x, cache, position):
    """One-token decode against a fixed-size KV cache.

    cache: {"k": [B, T, K, hd], "v": ...}; ``position`` is the index of the
    new token (ring-written).  Returns (out [B,1,D], new cache).
    """
    B, _, _ = x.shape
    K, hd, Hp = dims.n_kv_heads, dims.head_dim, dims.hp
    pos = jnp.full((B, 1), position, jnp.int32)
    # decode 2D plan: contract D over `data` in place of FSDP weight gathers
    # (EXPERIMENTS.md §Perf iteration B); projections psum tiny activations.
    x = shard(x, None, None, ("data",))
    q = (x @ params["wq"]).reshape(B, 1, Hp, hd)
    k_new = (x @ params["wk"]).reshape(B, 1, K, hd)
    v_new = (x @ params["wv"]).reshape(B, 1, K, hd)
    q = shard(q, ("pod", "data"), None, "model", None)
    q = apply_rope(q, pos, dims.rope_theta)
    k_new = apply_rope(k_new, pos, dims.rope_theta)
    # cache stores Hp expanded heads (aligned with the TP head shard); the
    # Hp/K memory amplification for low-kv archs is a known trade-off tracked
    # in EXPERIMENTS.md §Perf (grouped-KV decode removes it).
    idx = dims.kv_expand_idx()
    k_new = jnp.take(k_new, idx, axis=2)
    v_new = jnp.take(v_new, idx, axis=2)

    T = cache["k"].shape[1]
    slot = position % T
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)

    s = _gqa_scores(q, ck)  # [B,H,1,T]
    kpos = jnp.arange(T)
    visible = kpos[None, None, None, :] <= position
    # ring semantics: when the cache is exactly the window (T <= w) every
    # resident slot is in-window by construction; only a larger cache needs
    # the explicit sliding mask.
    if dims.sliding_window and T > dims.sliding_window:
        visible &= kpos[None, None, None, :] > position - dims.sliding_window
    s = jnp.where(visible, s, NEG)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
    o = _mask_pad_heads(_gqa_out(p, cv), dims).reshape(B, 1, Hp * hd)
    o = shard(o, None, None, "model")
    out = o @ params["wo"]
    return shard(out, None, None, ("data",)), {"k": ck, "v": cv}


def attn_cross(params, dims: AttnDims, x, enc_kv, *, q_chunk: int = 512):
    """Cross-attention (decoder over encoder KV, non-causal)."""
    B, S, _ = x.shape
    Hp, hd = dims.hp, dims.head_dim
    q = (x @ params["wq"]).reshape(B, S, Hp, hd)  # no RoPE on cross-attn
    q = shard(q, ("pod", "data"), None, "model", None)
    o = attend_chunked(q, enc_kv["k"], enc_kv["v"], causal=False, q_chunk=q_chunk)
    o = _mask_pad_heads(o, dims)
    o = o.reshape(B, S, Hp * hd)
    return o @ params["wo"]


def cross_kv(params, dims: AttnDims, enc_out):
    B, T, _ = enc_out.shape
    K, hd = dims.n_kv_heads, dims.head_dim
    k = (enc_out @ params["wk"]).reshape(B, T, K, hd)
    v = (enc_out @ params["wv"]).reshape(B, T, K, hd)
    idx = dims.kv_expand_idx()
    return {
        "k": shard(jnp.take(k, idx, axis=2), ("pod", "data"), None, "model", None),
        "v": shard(jnp.take(v, idx, axis=2), ("pod", "data"), None, "model", None),
    }


def init_cache(dims: AttnDims, B: int, T: int, dtype) -> dict:
    Hp, hd = dims.hp, dims.head_dim
    return {
        "k": jnp.zeros((B, T, Hp, hd), dtype),
        "v": jnp.zeros((B, T, Hp, hd), dtype),
    }
