"""Selective state-space blocks: Mamba1 (falcon-mamba) and Mamba2 (zamba2).

TPU adaptation (DESIGN.md §3): the CUDA selective-scan kernel becomes a
*chunked associative scan* — sequential ``lax.scan`` over chunks carrying the
[B, ..., N] state, parallel ``lax.associative_scan`` inside each chunk.  The
working set is one chunk's [B, cl, d_inner, N] element tensor (VMEM-scale)
instead of the full sequence, and the recurrence h_t = a_t*h_{t-1} + b_t is
exactly the first-order linear-recurrence monoid:
    (a1, b1) . (a2, b2) = (a1*a2, a2*b1 + b2).
Decode is the O(1) single-step update on the carried state.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, shard


@dataclasses.dataclass(frozen=True)
class SSMDims:
    d_model: int
    d_state: int
    d_conv: int = 4
    expand: int = 2
    version: int = 1  # 1 = mamba1, 2 = mamba2
    n_heads: int = 0  # mamba2: value heads; 0 -> d_inner // 64

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, self.d_model // 16)

    @property
    def heads(self) -> int:
        return self.n_heads or max(1, self.d_inner // 64)

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.heads


# --------------------------------------------------------------------------
# linear-recurrence scan
# --------------------------------------------------------------------------


def _lr_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def chunked_linear_scan(a, b, h0, chunk: int):
    """h_t = a_t * h_{t-1} + b_t along axis=1 (time).

    a, b: [B, L, ...]; h0: [B, ...].  Returns (h_all [B, L, ...], h_last).
    """
    B, L = a.shape[0], a.shape[1]
    cl = min(chunk, L)
    n = L // cl
    assert L % cl == 0, (L, cl)
    a_c = a.reshape(B, n, cl, *a.shape[2:]).swapaxes(0, 1)
    b_c = b.reshape(B, n, cl, *b.shape[2:]).swapaxes(0, 1)

    def body(h, xs):
        ac, bc = xs  # [B, cl, ...]
        # prefix-combine within the chunk (log-depth, vectorized)
        pa, pb = jax.lax.associative_scan(_lr_combine, (ac, bc), axis=1)
        # fold in the carried state: h_t = pa_t * h0 + pb_t
        hs = pa * h[:, None] + pb
        return hs[:, -1], hs

    h_last, h_all = jax.lax.scan(body, h0, (a_c, b_c))
    out_tail = jnp.broadcast_shapes(a.shape, b.shape)[2:]
    h_all = h_all.swapaxes(0, 1).reshape(B, L, *out_tail)
    return h_all, h_last


def causal_conv1d(x, w, prev=None):
    """Depthwise causal conv along time.  x: [B, L, C]; w: [K, C].

    ``prev``: [B, K-1, C] left context (decode / chunk continuation).
    Returns (y [B, L, C], new_prev [B, K-1, C]).
    """
    K = w.shape[0]
    B, L, C = x.shape
    if prev is None:
        prev = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)  # [B, L+K-1, C]
    y = sum(xp[:, i : i + L, :] * w[i][None, None, :] for i in range(K))
    return y, xp[:, -(K - 1) :, :] if K > 1 else jnp.zeros((B, 0, C), x.dtype)


# --------------------------------------------------------------------------
# Mamba1 (falcon-mamba)
# --------------------------------------------------------------------------


def init_mamba(key, dims: SSMDims, dtype) -> dict:
    ks = jax.random.split(key, 8)
    D, Di, N, R = dims.d_model, dims.d_inner, dims.d_state, dims.dt_rank
    p = {
        "in_proj": dense_init(ks[0], (D, 2 * Di), dtype),
        "conv_w": dense_init(ks[1], (dims.d_conv, Di), dtype, fan_in=dims.d_conv),
        "out_proj": dense_init(ks[2], (Di, D), dtype, fan_in=Di),
        "D": jnp.ones((Di,), jnp.float32),
    }
    if dims.version == 1:
        p |= {
            "x_proj": dense_init(ks[3], (Di, R + 2 * N), dtype),
            "dt_proj": dense_init(ks[4], (R, Di), jnp.float32, fan_in=R),
            "dt_bias": jnp.zeros((Di,), jnp.float32),
            # S4D-real init: A = -(1..N) per channel
            "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32), (Di, 1))),
        }
    else:  # mamba2: per-head scalar decay + direct B,C,dt projections
        H = dims.heads
        p |= {
            "bc_proj": dense_init(ks[3], (D, 2 * N), dtype),
            "dt_proj": dense_init(ks[4], (D, H), jnp.float32),
            "dt_bias": jnp.zeros((H,), jnp.float32),
            "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
            "gate_norm": jnp.zeros((Di,), dtype),
            "D": jnp.ones((H,), jnp.float32),
        }
    return p


def _mamba1_abx(params, dims: SSMDims, xc):
    """Per-step SSM coefficients from the conv output.  xc: [B, L, Di]."""
    N, R = dims.d_state, dims.dt_rank
    proj = xc @ params["x_proj"]  # [B, L, R+2N]
    dt_r, Bc, Cc = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        dt_r.astype(jnp.float32) @ params["dt_proj"] + params["dt_bias"]
    )  # [B, L, Di]
    A = -jnp.exp(params["A_log"])  # [Di, N]
    a = jnp.exp(dt[..., None] * A[None, None])  # [B, L, Di, N]
    bx = (dt * xc.astype(jnp.float32))[..., None] * Bc.astype(jnp.float32)[..., None, :]
    return a, bx, Cc


def mamba_forward(params, dims: SSMDims, x, state=None, conv_prev=None, chunk: int = 256):
    """Full-sequence forward.  x: [B, L, D].

    Returns (y [B, L, D], (ssm_state, conv_tail)) so prefill can hand the
    recurrent state to decode.
    """
    B, L, D = x.shape
    Di, N = dims.d_inner, dims.d_state
    decode = L == 1
    if decode:
        # decode-2D plan (EXPERIMENTS.md §Perf B3): contract D over `data`
        # in place instead of gathering FSDP shards of in/out_proj per token
        x = shard(x, None, None, ("data",))
    xi, z = jnp.split(x @ params["in_proj"], 2, axis=-1)  # [B, L, Di] each
    xi = shard(xi, ("pod", "data"), None, "model")
    xc, conv_tail = causal_conv1d(xi, params["conv_w"], conv_prev)
    xc = jax.nn.silu(xc)

    if dims.version == 1:
        a, bx, Cc = _mamba1_abx(params, dims, xc)
        h0 = state if state is not None else jnp.zeros((B, Di, N), jnp.float32)
        hs, h_last = chunked_linear_scan(a, bx, h0, chunk)
        y = jnp.einsum("blin,bln->bli", hs, Cc.astype(jnp.float32))
        y = y + params["D"][None, None] * xc.astype(jnp.float32)
        y = y.astype(x.dtype) * jax.nn.silu(z)
    else:
        H, P = dims.heads, dims.head_dim
        bc = x @ params["bc_proj"]
        Bc, Cc = jnp.split(bc.astype(jnp.float32), 2, axis=-1)  # [B, L, N]
        dt = jax.nn.softplus(
            x.astype(jnp.float32) @ params["dt_proj"] + params["dt_bias"]
        )  # [B, L, H]
        A = -jnp.exp(params["A_log"])  # [H]
        a = jnp.exp(dt * A[None, None])[..., None, None]  # [B, L, H, 1, 1]
        xh = xc.reshape(B, L, H, P).astype(jnp.float32)
        bx = (dt[..., None] * xh)[..., None] * Bc[:, :, None, None, :]  # [B,L,H,P,N]
        h0 = state if state is not None else jnp.zeros((B, H, P, N), jnp.float32)
        hs, h_last = chunked_linear_scan(a, bx, h0, chunk)
        y = jnp.einsum("blhpn,bln->blhp", hs, Cc)
        y = y + params["D"][None, None, :, None] * xh
        y = y.reshape(B, L, Di)
        from repro.models.common import rmsnorm

        y = rmsnorm(y.astype(x.dtype) * jax.nn.silu(z), params["gate_norm"], 1e-5)

    yv = y.astype(x.dtype)
    if decode:
        yv = shard(yv, None, None, "model")
        out = yv @ params["out_proj"]
        out = shard(out, None, None, ("data",))
    else:
        out = yv @ params["out_proj"]
    return out, (h_last, conv_tail)


def mamba_decode(params, dims: SSMDims, x, state, conv_prev):
    """Single-token step.  x: [B, 1, D]; O(1) state update."""
    y, (h, tail) = mamba_forward(params, dims, x, state=state, conv_prev=conv_prev, chunk=1)
    return y, (h, tail)


def init_ssm_state(dims: SSMDims, B: int, dtype=jnp.bfloat16):
    if dims.version == 1:
        h = jnp.zeros((B, dims.d_inner, dims.d_state), jnp.float32)
    else:
        h = jnp.zeros((B, dims.heads, dims.head_dim, dims.d_state), jnp.float32)
    conv = jnp.zeros((B, dims.d_conv - 1, dims.d_inner), dtype)
    return h, conv
