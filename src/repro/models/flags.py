"""Launch-time model flags.

TP_PAD: attention head counts are padded up to a multiple of this value so
the head axis shards evenly over the ``model`` mesh axis.  Pad heads are
masked dead weight: zero-initialized, output-masked, provably zero-gradient
(DESIGN.md §6) — model math is exactly the published architecture.  Set to
the model-axis size by launchers/dry-run (16); defaults to 1 (no padding) so
smoke tests see unpadded shapes.
"""
_TP_PAD = 1
_BATCH_AXES: tuple = ("pod", "data")


def set_tp_pad(n: int) -> None:
    global _TP_PAD
    _TP_PAD = max(1, int(n))


def tp_pad() -> int:
    return _TP_PAD


def pad_heads(h: int) -> int:
    p = _TP_PAD
    return ((h + p - 1) // p) * p


def set_batch_axes(axes: tuple) -> None:
    """ZeRO-3 strategy folds the model axis into the batch (pure DP)."""
    global _BATCH_AXES
    _BATCH_AXES = tuple(axes)


def batch_axes() -> tuple:
    return _BATCH_AXES
