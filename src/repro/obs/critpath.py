"""Causal critical-path analysis over Holoscope traces
(docs/observability.md §5).

The paper's latency claim is a claim about *paths*: "the end-to-end latency
is determined by the slowest path in the tree."  End-to-end percentiles
measure that; this module *explains* it.  For every accepted window emission
it reconstructs the causal chain of trace records that actually gated the
emission and attributes the chain's length to phases, so all-to-all vs
ring/hypercube vs the Flink tree can be compared causally, not just by
output percentiles.

**DAG construction (Holon).**  A window (pid, wid) emits when the emitting
node's global watermark — ``min`` over its per-partition ``progress``
lattice — passes the window end.  The :class:`WatermarkTracker` replays that
lattice exactly from trace records:

* ``exec.batch`` (with its ``wm`` arg) raises the folding node's own lane to
  the batch watermark — a **fold** chain element anchored at the batch's
  availability time;
* every ``net.msg`` ``cls="sync"`` send snapshots the sender's lane map
  (deltas always ship the full ``progress`` vector), keyed by the scheduled
  delivery time;
* ``sync.recv`` joins the matched snapshot in, elementwise max; a lane the
  delivery *advanced* gets a **merge** element whose causal parent is the
  sender's element — the last-arriving dominated delta is the parent at each
  merge, exactly the protocol's rule;
* ``ckpt.apply`` (with its stored ``wm`` vector) tracks the durable
  snapshot per partition; ``steal.adopt`` from a checkpoint joins it in as
  **adopt** elements parented on the apply — the recovery edge;
* ``node.restart`` resets the node's lanes (volatile state wiped).

At an accepted ``emit`` the **binding lane** is the laggard: the lane with
the minimum reconstructed watermark at that instant (lowest lane id on
ties).  Its chain, walked parent-to-root, is the critical path.  Because the
replay mirrors the real lattice exactly, the binding value is ``>=`` the
window end, and every chain element's event time is ``>=`` its watermark
value (event time and sim time advance together), so the path anchor is
``>=`` the window close — **path length <= end-to-end latency**, property-
tested in tests/test_critpath.py.

**Phase taxonomy.**  Walking emit -> root partitions the path interval
exactly (segments telescope, so the phase sums equal the path length):

* ``queue``    — batch wait before dequeue + emission/poll lag after the
                 gating event;
* ``compute``  — modeled fold cost ahead of the root batch (executor busy
                 on other batches between availability and dequeue);
* ``sync_wait``— value ready at the sender, waiting for the next sync round
                 to schedule this link;
* ``loss_stall``— sent but lost: gap between the first send attempt
                 carrying the value and the transmission that survived
                 (plus reliable-tier RTO retransmits and partition parking);
* ``wire``     — in flight on the surviving transmission;
* ``recovery`` — checkpoint-apply -> adoption edges after a crash (and, for
                 the baseline, job-down overlap).

**Flink baseline.**  The tree's slowest path is reconstructed from
``shuffle.fwd`` / ``shuffle.arrive`` pairs: the binding arrival is the last
one per window (the root emits at that instant); its leaf fold anchors the
path, and the reliable-tier ``retries`` arg on the matched ``net.msg``
splits delivery into wire vs RTO stalls.

Everything here is a pure function of the trace: same seed => byte-identical
reports (``CritPathReport.to_json``).
"""
from __future__ import annotations

import dataclasses
import json
from bisect import bisect_left
from collections import deque
from typing import Iterable

from repro.obs.records import TraceBuffer, TraceEvent
from repro.obs.registry import summary

PHASES = ("queue", "compute", "sync_wait", "loss_stall", "wire", "recovery")

# chain-walk safety bound: no real chain approaches this (each hop strictly
# advances sim time by at least one sync delivery)
_MAX_HOPS = 4096
# pending sync-snapshot bound per link (monitor mode keeps memory bounded;
# entries are matched at delivery, so steady state holds a round or two)
_PENDING_CAP = 4096


class _Elem:
    """One causal chain element: how a lane's watermark value became known
    at a node.  Immutable once created; ``parent`` links form the DAG."""

    __slots__ = ("kind", "t_ms", "node", "parent", "avail", "send_t", "link")

    def __init__(self, kind, t_ms, node, parent=None, avail=0.0,
                 send_t=0.0, link=None):
        self.kind = kind  # "init" | "fold" | "merge" | "ckpt" | "adopt"
        self.t_ms = t_ms  # when the value became known at ``node``
        self.node = node
        self.parent = parent
        self.avail = avail  # fold: batch availability time
        self.send_t = send_t  # merge: surviving transmission's send time
        self.link = link  # merge: (src, dst)

    def root(self, max_hops: int = _MAX_HOPS) -> "_Elem":
        e = self
        for _ in range(max_hops):
            if e.parent is None:
                return e
            e = e.parent
        return e


_INIT = _Elem("init", 0.0, None)


class WatermarkTracker:
    """Incremental replay of the per-node progress lattice from trace
    records (shared by the post-hoc analyzer and the online monitor).

    Bounded memory: per-node lane maps are O(P); pending sync snapshots are
    bounded per link and pruned by delivery-time staleness.  Feeding is
    passive — pure bookkeeping, no RNG, no sim interaction."""

    def __init__(self, num_partitions: int = 0, track_attempts: bool = False,
                 pending_cap: int = _PENDING_CAP):
        self.P = int(num_partitions)
        self.track_attempts = track_attempts
        self.pending_cap = int(pending_cap)
        # node -> {lane: (value, elem)}; missing lane = (0, init)
        self.lanes: dict = {}
        # (src, dst) -> deque[(deliver_t, snapshot dict)]
        self.pending: dict = {}
        # (src, dst) -> sorted send-attempt times of cls="sync" (incl. lost)
        self.attempts: dict = {}
        # pid -> (wm tuple, ckpt elem) of the stored checkpoint
        self.store: dict = {}
        self.shared_seen = False  # any sync traffic observed yet

    # ---- lattice access ----------------------------------------------------
    def _lane(self, node, lane) -> tuple:
        return self.lanes.get(node, {}).get(lane, (0, _INIT))

    def binding(self, node, pid: int) -> tuple:
        """(lane, value, elem) gating an emit at ``node`` for ``pid``: the
        laggard lane under sync'd state, the partition's own lane otherwise
        (local-only queries have per-partition watermarks)."""
        if not self.shared_seen:
            v, e = self._lane(node, pid)
            return pid, v, e
        best = None
        for lane in range(self.P):
            v, e = self._lane(node, lane)
            if best is None or v < best[1]:
                best = (lane, v, e)
        return best if best is not None else (pid, *self._lane(node, pid))

    # ---- record feed -------------------------------------------------------
    def feed(self, ev: TraceEvent) -> None:
        kind = ev.kind
        if kind == "exec.batch":
            wm = ev.arg("wm")
            if wm is None:
                return  # baseline exec records carry no lattice provenance
            self.P = max(self.P, ev.partition + 1)
            node = self.lanes.setdefault(ev.node, {})
            cur = node.get(ev.partition, (0, _INIT))
            if wm > cur[0]:
                node[ev.partition] = (wm, _Elem(
                    "fold", ev.t_ms, ev.node,
                    avail=ev.t_ms - float(ev.arg("queue_ms", 0.0)),
                ))
        elif kind == "net.msg" and ev.cls == "sync":
            self.shared_seen = True
            link = (ev.src, ev.dst)
            if self.track_attempts:
                self.attempts.setdefault(link, []).append(ev.t_ms)
            if ev.status == "ok":
                q = self.pending.get(link)
                if q is None:
                    q = self.pending[link] = deque(maxlen=self.pending_cap)
                # snapshot the sender's lane map at send time: this IS the
                # progress vector the delta ships (deltas always carry full
                # progress), keyed by the scheduled delivery time
                q.append((ev.t_end_ms, ev.t_ms,
                          dict(self.lanes.get(ev.src, {}))))
        elif kind == "sync.recv":
            self.shared_seen = True
            hit = self._match(ev.src, ev.node, ev.t_ms)
            if hit is None or ev.status not in ("delta_merge", "full_merge"):
                return
            t_send, snap = hit
            node = self.lanes.setdefault(ev.node, {})
            for lane, (v, e) in snap.items():
                self.P = max(self.P, lane + 1)
                if v > node.get(lane, (0, _INIT))[0]:
                    node[lane] = (v, _Elem(
                        "merge", ev.t_ms, ev.node, parent=e,
                        send_t=t_send, link=(ev.src, ev.node),
                    ))
        elif kind == "ckpt.apply":
            if ev.status == "applied":
                wm = ev.arg("wm")
                if wm:
                    self.P = max(self.P, len(wm))
                    self.store[ev.partition] = (
                        wm, _Elem("ckpt", ev.t_ms, ev.node))
        elif kind == "steal.adopt":
            if ev.status == "ckpt":
                stored = self.store.get(ev.partition)
                if stored is None:
                    return
                wm, ck_elem = stored
                node = self.lanes.setdefault(ev.node, {})
                for lane, v in enumerate(wm):
                    if v > node.get(lane, (0, _INIT))[0]:
                        node[lane] = (v, _Elem(
                            "adopt", ev.t_ms, ev.node, parent=ck_elem))
        elif kind == "node.restart":
            self.lanes.pop(ev.node, None)  # volatile state wiped

    def _match(self, src, dst, t_recv: float):
        """Pop the pending ``(send time, snapshot)`` whose scheduled delivery
        is ``t_recv`` (delivery times are exact floats shared by record and
        callback); prunes stale undelivered entries (receiver was dead)."""
        q = self.pending.get((src, dst))
        if not q:
            return None
        horizon = t_recv - 60_000.0
        for i, (t_del, t_send, snap) in enumerate(q):
            if t_del == t_recv:
                del q[i]
                return t_send, snap
        while q and q[0][0] < horizon:
            q.popleft()
        return None

    def _send_t(self, elem: _Elem) -> float:
        return elem.send_t

    def first_attempt(self, link, t_lo: float, t_hi: float) -> float:
        """Earliest sync send attempt on ``link`` in [t_lo, t_hi] — when the
        value first had a chance to ship (post-hoc only)."""
        at = self.attempts.get(link)
        if at:
            i = bisect_left(at, t_lo)
            if i < len(at) and at[i] <= t_hi:
                return at[i]
        return t_hi


# ---------------------------------------------------------------------------
# post-hoc analysis
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CritPath:
    """The critical path of one accepted window emission."""

    partition: int
    window: int
    node: object  # emitting node
    origin: object  # root chain element's node (the causal source)
    t_emit_ms: float
    latency_ms: float  # consumer-visible end-to-end latency
    path_ms: float  # anchor -> emit along the causal chain (<= latency)
    hops: int  # merge/adopt edges on the path
    phases: dict  # phase -> ms; sums to path_ms exactly

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["phases"] = {k: round(v, 3) for k, v in sorted(d["phases"].items())}
        for k in ("t_emit_ms", "latency_ms", "path_ms"):
            d[k] = round(d[k], 3)
        return d


@dataclasses.dataclass
class CritPathReport:
    system: str  # "holon" | "flink"
    topology: str  # gossip topology name, or "tree" for the baseline
    paths: list

    def summary(self) -> dict:
        """Deterministic distribution summary: hop counts, path lengths, and
        per-phase attribution (avg ms + fraction of total path time)."""
        out: dict = {"system": self.system, "topology": self.topology,
                     "n": len(self.paths)}
        if not self.paths:
            return out
        out["hops"] = summary([float(p.hops) for p in self.paths])
        out["path_ms"] = summary([p.path_ms for p in self.paths])
        out["latency_ms"] = summary([p.latency_ms for p in self.paths])
        total = sum(p.path_ms for p in self.paths)
        out["phase_ms"] = {
            ph: round(sum(p.phases[ph] for p in self.paths) / len(self.paths), 6)
            for ph in PHASES
        }
        out["phase_frac"] = {
            ph: round(sum(p.phases[ph] for p in self.paths) / total, 6)
            if total > 0 else 0.0
            for ph in PHASES
        }
        return out

    def to_json(self) -> str:
        """Byte-stable serialization (same seed => identical string)."""
        return json.dumps(
            {
                "meta": "holon-critpath-v1",
                "system": self.system,
                "topology": self.topology,
                "summary": self.summary(),
                "paths": [p.as_dict() for p in self.paths],
            },
            sort_keys=True,
        )


def _overlap(spans: list, lo: float, hi: float) -> float:
    """Total overlap of sorted (start, end) spans with [lo, hi]."""
    if hi <= lo:
        return 0.0
    total = 0.0
    for s, e in spans:
        if s >= hi:
            break
        if e > lo:
            total += min(e, hi) - max(s, lo)
    return total


def _zero_phases() -> dict:
    return {ph: 0.0 for ph in PHASES}


def analyze(events: "Iterable[TraceEvent] | TraceBuffer",
            cfg=None) -> CritPathReport:
    """Reconstruct the critical path of every accepted emission in a trace.

    ``events`` must be a complete record stream in append order (pass the
    harness's ``TraceBuffer`` — spilled records are included).  ``cfg``
    (a ``SimConfig``) enables the reliable-tier RTO split for the baseline;
    everything else is self-contained in the trace."""
    if isinstance(events, TraceBuffer):
        events = events.all_events() if events.spilled else events.events()
    evs = list(events)
    flink = any(e.kind in ("shuffle.fwd", "shuffle.arrive", "flink.down",
                           "flink.barrier") for e in evs)
    return (_analyze_flink if flink else _analyze_holon)(evs, cfg)


def analyze_harness(harness) -> CritPathReport:
    """Analyze a finished harness run (Holon or Flink) via its telemetry."""
    return analyze(harness.obs.buf, cfg=harness.cfg)


def _exec_spans(evs: list) -> dict:
    """node -> sorted [(t, t_end)] modeled-compute spans."""
    spans: dict = {}
    for e in evs:
        if e.kind == "exec.batch" and e.t_end_ms > e.t_ms:
            spans.setdefault(e.node, []).append((e.t_ms, e.t_end_ms))
    for v in spans.values():
        v.sort()
    return spans


def _analyze_holon(evs: list, cfg) -> CritPathReport:
    tracker = WatermarkTracker(
        num_partitions=getattr(cfg, "num_partitions", 0) or 0,
        track_attempts=True,
    )
    topology = "local"
    bindings = []  # (emit event, value, elem)
    for ev in evs:
        tracker.feed(ev)
        if ev.kind == "sync.publish" and topology == "local":
            topology = str(ev.arg("topology", "all"))
        elif ev.kind == "emit" and ev.status == "accepted":
            lane, value, elem = tracker.binding(ev.node, ev.partition)
            bindings.append((ev, value, elem))
    spans = _exec_spans(evs)
    paths = []
    for ev, value, elem in bindings:
        phases = _zero_phases()
        t_hi = ev.t_ms
        e, hops, anchor = elem, 0, 0.0
        for _ in range(_MAX_HOPS):
            if e.kind == "fold":
                # emission/poll lag above the fold, then [avail, dequeue)
                # split into executor-busy (compute) vs idle batch wait
                phases["queue"] += t_hi - e.t_ms
                busy = _overlap(spans.get(e.node, ()), e.avail, e.t_ms)
                phases["compute"] += busy
                phases["queue"] += (e.t_ms - e.avail) - busy
                anchor = e.avail
                break
            if e.kind == "merge":
                phases["queue"] += t_hi - e.t_ms
                t_p = e.parent.t_ms
                att = tracker.first_attempt(e.link, t_p, e.send_t)
                att = min(max(att, t_p), e.send_t)
                phases["sync_wait"] += att - t_p
                phases["loss_stall"] += e.send_t - att
                phases["wire"] += e.t_ms - e.send_t
                hops += 1
                t_hi, e = t_p, e.parent
                continue
            if e.kind == "adopt":
                phases["queue"] += t_hi - e.t_ms
                phases["recovery"] += e.t_ms - e.parent.t_ms
                hops += 1
                t_hi, e = e.parent.t_ms, e.parent
                continue
            if e.kind == "ckpt":
                anchor = e.t_ms
                break
            # init root: nothing known before t=0
            anchor = 0.0
            break
        paths.append(CritPath(
            partition=ev.partition, window=ev.window, node=ev.node,
            origin=elem.root().node, t_emit_ms=ev.t_ms,
            latency_ms=float(ev.arg("latency_ms", 0.0)),
            path_ms=ev.t_ms - anchor, hops=hops, phases=phases,
        ))
    return CritPathReport(system="holon", topology=topology, paths=paths)


def _analyze_flink(evs: list, cfg) -> CritPathReport:
    rto = float(getattr(cfg, "net_rto_ms", 0.0) or 0.0)
    # (wid, pid) -> fwd times; shuffle net.msg ok sends FIFO per src link;
    # down spans for replay/recovery overlap
    fwds: dict = {}
    sends: dict = {}  # src -> deque[(t_send, t_deliver, retries)]
    downs: list = []
    down_start = None
    execs: dict = {}  # pid -> sorted [(t_fold, queue_ms)]
    for e in evs:
        if e.kind == "shuffle.fwd":
            fwds.setdefault((e.window, e.partition), []).append(
                (e.t_ms, e.node))
        elif e.kind == "net.msg" and e.cls == "shuffle" and e.status == "ok":
            sends.setdefault(e.src, deque()).append(
                (e.t_ms, e.t_end_ms, int(e.arg("retries", 0))))
        elif e.kind == "flink.down" and down_start is None:
            down_start = e.t_ms
        elif e.kind == "flink.recover" and down_start is not None:
            downs.append((down_start, e.t_ms))
            down_start = None
        elif e.kind == "exec.batch":
            execs.setdefault(e.partition, []).append(
                (e.t_ms, float(e.arg("queue_ms", 0.0))))
    if down_start is not None:
        downs.append((down_start, float("inf")))
    spans = _exec_spans(evs)
    # last arrival per window before its emit = the slowest (binding) path
    last_arrive: dict = {}
    paths = []
    for e in evs:
        if e.kind == "shuffle.arrive":
            last_arrive[e.window] = e
        elif e.kind == "emit" and e.status == "accepted":
            arr = last_arrive.get(e.window)
            if arr is None:
                continue
            phases = _zero_phases()
            pid = arr.partition
            # the forward that produced this arrival: latest fwd <= arrive
            cand = [f for f in fwds.get((e.window, pid), ()) if f[0] <= arr.t_ms]
            if not cand:
                continue
            t_fwd, leaf = cand[-1]
            # surviving transmission: pop the send delivering at arrive time
            t_send, retries = t_fwd, 0
            q = sends.get(leaf)
            if q:
                for i, (ts, td, r) in enumerate(q):
                    if td == arr.t_ms:
                        t_send, retries = ts, r
                        del q[i]
                        break
            stall = min(retries * rto, arr.t_ms - t_send) if rto else 0.0
            phases["loss_stall"] += (t_send - t_fwd)  # partition parking
            phases["loss_stall"] += stall  # RTO retransmits
            phases["wire"] += (arr.t_ms - t_send) - stall
            phases["queue"] += e.t_ms - arr.t_ms  # 0: root emits on arrival
            # leaf fold: availability -> dequeue, minus executor-busy overlap
            # and job-down (replay) overlap
            rec = execs.get(pid, ())
            qms = next((qm for tf, qm in reversed(rec) if tf == t_fwd), 0.0)
            avail = t_fwd - qms
            busy = _overlap(spans.get(leaf, ()), avail, t_fwd)
            down = _overlap(sorted(downs), avail, t_fwd)
            phases["compute"] += busy
            phases["recovery"] += max(0.0, min(down, (t_fwd - avail) - busy))
            phases["queue"] += (t_fwd - avail) - busy - phases["recovery"]
            paths.append(CritPath(
                partition=pid, window=e.window, node=e.node, origin=leaf,
                t_emit_ms=e.t_ms,
                latency_ms=float(e.arg("latency_ms", 0.0)),
                path_ms=e.t_ms - avail, hops=1, phases=phases,
            ))
    return CritPathReport(system="flink", topology="tree", paths=paths)
