"""Trace-driven protocol auditor (docs/observability.md §4).

The runtime is deterministic, so a trace of every protocol event is itself
replayable and checkable: the auditor walks the time-ordered records and
asserts the paper's invariants on what *actually happened*, turning the
suite's oracle-diff-only verification into invariant checking on every
traced run.

Invariants (violation ids in brackets):

* **[exactly-once]** — per (partition, window) exactly one ``emit`` with
  status ``accepted``; re-emissions must be ``duplicate`` and carry the same
  value digest (the consumer-dedup contract of paper §3.3).
* **[frontier-regression]** — the checkpoint store's applied frontier
  (``ckpt.apply`` → stored ``nxt_idx``) is monotone per partition:
  merge-on-put may never regress a checkpoint (Algorithm 2's lattice rule).
* **[domination]** — every applied delta merge (``sync.recv`` status
  ``delta_merge``) had a dominated baseline, and every non-dominated
  delivery was nacked — the causal delta-merging condition.
* **[unacked-merge]** — every merge that carried a marker is matched by a
  ``sync_ack`` send from the merging node to the sender at the same instant
  (cross-checked against the fabric's ``net.msg`` records, not the node's
  own claim); a missing ack would silently pin the sender's baseline.
* **[recovery-bound]** — after a crash, every partition the dead node owned
  is re-adopted by a live node within detection + steal + fetch time
  (requires ``cfg``; crashes overlapping a network partition are exempt —
  recovery then legitimately waits for storage/steal races to settle, and
  partitions a live node *already* co-owned at crash time need no
  re-adoption — sparse dissemination topologies, docs/protocol.md §5, can
  transiently duplicate ownership, which consumer dedup makes benign).
* **[truncated]** — the ring buffer dropped records: the auditor refuses to
  certify invariants it could not see.

Besides pass/fail the auditor extracts first-class timeline metrics:
``time_to_recover_ms`` per crash (crash → last owned-partition adoption) and
``time_to_settle_ms`` (first fault → last spiked-latency window), the
numbers behind the paper's 11x-under-failure claim.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Iterable

from repro.obs.records import TraceBuffer, TraceEvent

# audit slack on the recovery bound: scheduling quantization (poll loops,
# rebalance running on the heartbeat period) + one extra storage round trip
RECOVERY_SLACK_MS = 250.0


@dataclasses.dataclass
class AuditReport:
    ok: bool
    violations: list[str]
    metrics: dict

    def __str__(self) -> str:
        head = "AUDIT OK" if self.ok else f"AUDIT FAILED ({len(self.violations)})"
        lines = [head] + [f"  - {v}" for v in self.violations]
        for k in sorted(self.metrics):
            lines.append(f"  {k} = {self.metrics[k]}")
        return "\n".join(lines)


def _fault_windows(events: list[TraceEvent]) -> list[tuple[float, float]]:
    """[start, end) spans during which the fabric was partitioned."""
    spans, start = [], None
    for ev in events:
        if ev.kind == "net.partition" and start is None:
            start = ev.t_ms
        elif ev.kind == "net.heal" and start is not None:
            spans.append((start, ev.t_ms))
            start = None
    if start is not None:
        spans.append((start, float("inf")))
    return spans


def _overlaps(spans: list[tuple[float, float]], a: float, b: float) -> bool:
    return any(s < b and a < e for s, e in spans)


def audit(
    events: "Iterable[TraceEvent] | TraceBuffer",
    cfg=None,
    dropped: int = 0,
    spike_factor: float = 3.0,
) -> AuditReport:
    """Replay a trace and check every invariant above.  ``cfg`` (a
    ``SimConfig``) enables the recovery-bound check; ``dropped`` (taken from
    the buffer when one is passed) flags truncation."""
    if isinstance(events, TraceBuffer):
        dropped = events.dropped
        # spool + resident ring: a spill-configured buffer still certifies
        # long runs — spilled records are on disk, not dropped
        events = events.all_events() if events.spilled else events.events()
    evs = sorted(events, key=lambda e: e.t_ms)
    v: list[str] = []
    metrics: dict = {}

    if dropped:
        v.append(f"[truncated] trace ring dropped {dropped} records — "
                 "grow SimConfig.obs_trace_cap to certify this run")

    # ---- [exactly-once] ----------------------------------------------------
    accepted: dict[tuple[int, int], TraceEvent] = {}
    emits = [e for e in evs if e.kind == "emit"]
    for e in emits:
        key = (e.partition, e.window)
        if e.status == "accepted":
            if key in accepted:
                v.append(f"[exactly-once] window {key} accepted twice "
                         f"(t={accepted[key].t_ms:.1f} and t={e.t_ms:.1f})")
            else:
                accepted[key] = e
        elif e.status == "duplicate":
            first = accepted.get(key)
            if first is None:
                v.append(f"[exactly-once] window {key} duplicate at "
                         f"t={e.t_ms:.1f} precedes any accepted emission")
            elif e.arg("digest") != first.arg("digest"):
                v.append(f"[exactly-once] window {key} re-emitted with a "
                         f"different value digest at t={e.t_ms:.1f} "
                         "(non-deterministic replay)")
    metrics["windows_accepted"] = len(accepted)
    metrics["windows_duplicate"] = sum(1 for e in emits if e.status == "duplicate")
    metrics["windows_evicted"] = sum(1 for e in emits if e.status == "evicted")

    # ---- [frontier-regression] ---------------------------------------------
    frontier: dict[int, tuple[float, int]] = {}
    for e in evs:
        if e.kind != "ckpt.apply":
            continue
        nxt = int(e.arg("nxt_idx", 0))
        prev = frontier.get(e.partition)
        if prev is not None and nxt < prev[1]:
            v.append(f"[frontier-regression] partition {e.partition} stored "
                     f"frontier went {prev[1]} -> {nxt} at t={e.t_ms:.1f} "
                     f"(previous apply t={prev[0]:.1f})")
        frontier[e.partition] = (e.t_ms, max(nxt, prev[1] if prev else nxt))

    # ---- [domination] + [unacked-merge] ------------------------------------
    # multiset of fabric-recorded ack send attempts, keyed (t, from, to):
    # a merge and its ack are issued at the same sim instant
    acks: dict[tuple[float, object, object], int] = defaultdict(int)
    for e in evs:
        if e.kind == "net.msg" and e.cls == "sync_ack":
            acks[(e.t_ms, e.src, e.dst)] += 1
    merges = nacks = 0
    for e in evs:
        if e.kind != "sync.recv":
            continue
        dominated = bool(e.arg("dominated", 1))
        if e.status == "delta_merge":
            merges += 1
            if not dominated:
                v.append(f"[domination] node {e.node} merged a delta from "
                         f"{e.src} at t={e.t_ms:.1f} without dominating its "
                         "baseline (coverage gap would be silently lost)")
        elif e.status == "full_merge":
            merges += 1
        elif e.status == "nack":
            nacks += 1
            if dominated:
                v.append(f"[domination] node {e.node} nacked a dominated "
                         f"delta from {e.src} at t={e.t_ms:.1f}")
        if e.status in ("delta_merge", "full_merge") and e.arg("marker", 0):
            key = (e.t_ms, e.node, e.src)
            if acks[key] > 0:
                acks[key] -= 1
            else:
                v.append(f"[unacked-merge] node {e.node} merged from {e.src} "
                         f"at t={e.t_ms:.1f} but the fabric shows no sync_ack "
                         "send — the sender's baseline would stay pinned")
    metrics["sync_merges"] = merges
    metrics["sync_nacks"] = nacks

    # ---- [recovery-bound] + time-to-recover --------------------------------
    part_spans = _fault_windows(evs)
    adopts = [e for e in evs if e.kind == "steal.adopt"]
    # Ownership replay: under a sparse dissemination topology
    # (docs/protocol.md §5) a node's partial early view can make it adopt a
    # partition whose rendezvous owner is alive elsewhere — the partition is
    # then held (and processed) by both until the duplicate is handed off.
    # If the duplicating node crashes, no re-adoption is needed: the live
    # owner never stopped.  Replay boot/adopt/handoff/drain to know, at each
    # crash, which of the dead node's partitions were already live-covered.
    owners: dict[int, set] = defaultdict(set)
    alive: set = set()
    covered_at_crash: dict[int, set] = {}  # id(crash event) -> covered pids
    for e in evs:
        if e.kind == "node.boot":
            alive.add(e.node)
            for pid in e.arg("pids", ()):
                owners[int(pid)].add(e.node)
        elif e.kind == "steal.adopt":
            owners[e.partition].add(e.node)
        elif e.kind == "part.handoff":
            owners[e.partition].discard(e.node)
        elif e.kind == "node.drain":
            alive.discard(e.node)
            for pid in e.arg("owned", ()):
                owners[int(pid)].discard(e.node)
        elif e.kind == "node.crash":
            alive.discard(e.node)
            covered_at_crash[id(e)] = {
                int(pid) for pid in e.arg("owned", ())
                if owners[int(pid)] & alive
            }
            for pid in e.arg("owned", ()):
                owners[int(pid)].discard(e.node)
    ttr: dict[int, float] = {}
    for e in evs:
        if e.kind != "node.crash":
            continue
        owned = e.arg("owned", ())
        if not owned:
            continue
        bound = float("inf")
        if cfg is not None:
            # detection (timeout + up to 2 control periods) + steal handshake
            # + checkpoint fetch over the storage link + scheduling slack
            bound = (cfg.hb_timeout_ms + 2.0 * cfg.hb_interval_ms
                     + cfg.steal_delay_ms + 2.0 * cfg.storage_rtt_ms
                     + RECOVERY_SLACK_MS)
        deadline = e.t_ms + bound
        last = e.t_ms
        for pid in owned:
            if int(pid) in covered_at_crash.get(id(e), ()):
                # a live node already held this partition at crash time
                # (duplicate ownership from a sparse-view steal) — recovery
                # is instantaneous, nothing to re-adopt
                continue
            took = [a for a in adopts
                    if a.partition == pid and a.t_ms > e.t_ms and a.node != e.node]
            if not took:
                # the crashed node may have restarted and re-adopted its own
                # partitions, or the run ended first — only flag when a bound
                # is checkable and no partition overlapped the interval
                if (cfg is not None
                        and not _overlaps(part_spans, e.t_ms, deadline)
                        and any(a.t_ms > deadline for a in evs[-1:])):
                    v.append(f"[recovery-bound] partition {pid} of crashed "
                             f"node {e.node} (t={e.t_ms:.1f}) was never "
                             "re-adopted by a live node")
                continue
            t_adopt = min(a.t_ms for a in took)
            last = max(last, t_adopt)
            if (cfg is not None and t_adopt > deadline
                    and not _overlaps(part_spans, e.t_ms, t_adopt)):
                v.append(f"[recovery-bound] partition {pid} of crashed node "
                         f"{e.node} re-adopted {t_adopt - e.t_ms:.0f}ms after "
                         f"the crash (bound {bound:.0f}ms)")
        ttr[e.node] = last - e.t_ms
    if ttr:
        metrics["time_to_recover_ms"] = {n: round(t, 3) for n, t in sorted(ttr.items())}

    # centralized-baseline downtime (flink.down -> first flink.recover after)
    downs = [e.t_ms for e in evs if e.kind == "flink.down"]
    recovers = [e.t_ms for e in evs if e.kind == "flink.recover"]
    if downs:
        spans = []
        for d in downs:
            after = [r for r in recovers if r >= d]
            spans.append(round((after[0] - d), 3) if after else float("inf"))
        metrics["flink_downtime_ms"] = spans

    # ---- time-to-settle ----------------------------------------------------
    faults = [e.t_ms for e in evs
              if e.kind in ("node.crash", "net.partition", "net.degrade")]
    if faults:
        t0 = min(faults)
        pre = [float(e.arg("latency_ms", 0.0)) for e in emits
               if e.status == "accepted" and e.t_ms < t0]
        if pre:
            pre.sort()
            thr = spike_factor * max(pre[len(pre) // 2], 1.0)
            spiked = [e.t_ms for e in emits
                      if e.status == "accepted" and e.t_ms >= t0
                      and float(e.arg("latency_ms", 0.0)) > thr]
            metrics["time_to_settle_ms"] = (
                round(max(spiked) - t0, 3) if spiked else 0.0
            )
    return AuditReport(ok=not v, violations=v, metrics=metrics)


def audit_harness(harness, cfg=None) -> AuditReport:
    """Audit a finished harness run (Holon or Flink) via its telemetry."""
    return audit(harness.obs.buf, cfg=cfg if cfg is not None else harness.cfg)
