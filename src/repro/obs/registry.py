"""Metrics registry: counters / gauges / histograms keyed by labels, with
sim-time snapshots (docs/observability.md §1).

Metrics are identified by ``name`` + key-sorted labels (e.g.
``batches_folded{node=3}``), so collection order is deterministic and two
same-seed runs collect byte-identical values.  ``snapshot(t_ms)`` appends the
current values to an in-memory timeseries on **simulated** timestamps — the
registry never reads the wall clock, so metrics cannot perturb a run or
conflate sim-time with wall-time (that split lives in obs/timing.py).

Histograms use fixed power-of-two bucket edges: observation is O(log B) with
no allocation, percentiles are bucket-resolution approximations (exact
percentiles for benchmark headline numbers come from :func:`summary` over the
raw values — the one shared implementation behind ``Consumer.latency_stats``
and the ``benchmarks/common.py`` helpers).
"""
from __future__ import annotations

import bisect
import math
from typing import Iterable

# bucket upper edges: 1, 2, 4, … 2^19 ms (~8.7 min), then +inf
_EDGES = tuple(float(1 << i) for i in range(20))


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def collect(self) -> dict[str, float]:
        return {"": self.value}


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def collect(self) -> dict[str, float]:
        return {"": self.value}


class Histogram:
    __slots__ = ("buckets", "count", "sum", "min", "max")

    def __init__(self):
        self.buckets = [0] * (len(_EDGES) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.buckets[bisect.bisect_left(_EDGES, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def avg(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def percentile(self, q: float) -> float:
        """Bucket-resolution percentile (upper edge of the q-quantile
        bucket, clamped to the observed max) — cheap, deterministic, good
        enough for per-phase breakdown rows."""
        if not self.count:
            return float("nan")
        rank = q / 100.0 * self.count
        acc = 0
        for i, n in enumerate(self.buckets):
            acc += n
            if acc >= rank and n:
                edge = _EDGES[i] if i < len(_EDGES) else self.max
                return float(min(edge, self.max))
        return float(self.max)

    def collect(self) -> dict[str, float]:
        return {".count": self.count, ".sum": self.sum}


class MetricsRegistry:
    """All metrics of one deployment.  ``counter``/``gauge``/``histogram``
    get-or-create; ``collect`` returns a key-sorted flat mapping; ``snapshot``
    appends it to the sim-time ``series``."""

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self.series: list[tuple[float, dict[str, float]]] = []

    @staticmethod
    def _key(name: str, labels: dict) -> str:
        if not labels:
            return name
        inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
        return f"{name}{{{inner}}}"

    def _get(self, ctor, name: str, labels: dict):
        key = self._key(name, labels)
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = ctor()
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def collect(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for key in sorted(self._metrics):
            for suffix, v in self._metrics[key].collect().items():
                out[key + suffix] = v
        return out

    def snapshot(self, t_ms: float) -> None:
        self.series.append((float(t_ms), self.collect()))

    def histograms(self, name: str) -> dict[str, Histogram]:
        """All histograms whose metric name matches ``name`` (any labels)."""
        return {
            k: m
            for k, m in self._metrics.items()
            if isinstance(m, Histogram) and (k == name or k.startswith(name + "{"))
        }


def summary(values: Iterable[float]) -> dict[str, float]:
    """Exact latency summary — THE shared percentile implementation: both
    ``Consumer.latency_stats`` and the benchmark row helpers call this, so
    avg/p50/p99 can never drift between reports."""
    import numpy as np

    xs = np.asarray(list(values), dtype=np.float64)
    if xs.size == 0:
        return {"avg": float("nan"), "p50": float("nan"), "p99": float("nan"),
                "max": float("nan"), "n": 0}
    return {
        "avg": float(np.mean(xs)),
        "p50": float(np.percentile(xs, 50)),
        "p99": float(np.percentile(xs, 99)),
        "max": float(np.max(xs)),
        "n": int(xs.size),
    }
