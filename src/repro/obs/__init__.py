"""Holoscope: deterministic telemetry for the Holon runtimes.

Three layers (docs/observability.md), all strictly passive — no RNG draws,
no wall-clock reads in sim paths, no simulator events that could perturb the
run being observed:

* **metrics registry** (obs/registry.py) — counters/gauges/histograms keyed
  by node/partition/class, snapshotted on sim-time intervals;
* **structured span tracing** (obs/records.py, obs/telemetry.py) — typed
  records of the full protocol lifecycle in a bounded ring buffer, exported
  to JSONL and Chrome trace-event format (Perfetto timelines);
* **protocol auditor** (obs/audit.py) — replays a trace and asserts the
  paper's invariants (exactly-once, monotone frontiers, causal domination,
  acked merges, bounded recovery), extracting time-to-recover and
  time-to-settle as first-class metrics;
* **critical-path analyzer** (obs/critpath.py) — reconstructs, per emitted
  window, the causal chain that gated the emission (fold → sync hops →
  merge → emit) and attributes its length to phases, per topology;
* **online monitor** (obs/monitor.py) — the auditor's invariants plus
  operational health alerts, incrementally in bounded memory over the live
  telemetry stream.

Determinism is the contract: a same-seed run exports a byte-identical
trace, which is what makes the trace auditable at all.
"""
from repro.obs.audit import AuditReport, audit, audit_harness
from repro.obs.critpath import (
    CritPath,
    CritPathReport,
    WatermarkTracker,
    analyze,
    analyze_harness,
)
from repro.obs.monitor import Alert, OnlineMonitor, replay
from repro.obs.records import (
    TraceBuffer,
    TraceEvent,
    event_json,
    from_jsonl,
    mkargs,
    to_chrome,
    to_jsonl,
)
from repro.obs.registry import Counter, Gauge, Histogram, MetricsRegistry, summary
from repro.obs.telemetry import Telemetry
from repro.obs.timing import SimTimer, WallTimer

__all__ = [
    "AuditReport",
    "audit",
    "audit_harness",
    "CritPath",
    "CritPathReport",
    "WatermarkTracker",
    "analyze",
    "analyze_harness",
    "Alert",
    "OnlineMonitor",
    "replay",
    "TraceBuffer",
    "TraceEvent",
    "event_json",
    "from_jsonl",
    "mkargs",
    "to_chrome",
    "to_jsonl",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "summary",
    "Telemetry",
    "SimTimer",
    "WallTimer",
]
