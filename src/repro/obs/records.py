"""Typed trace records + bounded ring buffer (docs/observability.md §2).

One :class:`TraceEvent` per protocol occurrence — fabric message, batch
fold, window emission, sync merge, checkpoint put/get, crash/steal/recover,
join/drain — replacing the fabric's old ad-hoc ``(t, src, dst, …)`` tuples.
Records are frozen and slotted: equality is field-wise, so "same seed ⇒
identical trace" is a plain ``==`` over two runs, and creation stays cheap
enough for hot paths.

Every timestamp is **simulated** milliseconds (``Sim.now``); recording makes
no RNG draws and schedules no simulator events, so tracing can never perturb
the run it observes — determinism is what makes the trace auditable
(obs/audit.py, docs/observability.md §4).

The :class:`TraceBuffer` is a bounded ring: long chaos sweeps cannot grow
memory without bound — the oldest records fall off and ``dropped`` counts
them, which the auditor treats as "trace truncated" (it refuses to certify
invariants it cannot see).  With a ``spill_path`` configured
(``SimConfig.obs_spill_path``) evicted records stream to a JSONL spool
instead of vanishing: resident memory stays bounded at ``cap`` records while
``spilled_events()`` + the ring reconstruct the full stream, so long runs
stay auditable (docs/observability.md §3).
"""
from __future__ import annotations

import dataclasses
import json
from collections import deque
from typing import Any, Iterable


@dataclasses.dataclass(frozen=True, slots=True)
class TraceEvent:
    """One protocol occurrence.  ``kind`` names the span taxonomy entry
    (docs/observability.md §2); unused fields keep their defaults so records
    stay compact and field-wise comparable."""

    t_ms: float  # sim-time of the record (span start)
    kind: str  # taxonomy name, e.g. "net.msg", "exec.batch", "emit"
    node: Any = None  # primary actor: node id, "storage", or None
    partition: int = -1
    window: int = -1
    src: Any = None  # message source endpoint (net/sync records)
    dst: Any = None  # message destination endpoint
    cls: str = ""  # fabric message class ("sync", "hb", "ckpt_put", …)
    nbytes: float = 0.0
    status: str = ""  # e.g. "ok"/"lost"/"accepted"/"delta_merge"/"nack"
    t_end_ms: float = -1.0  # span end / scheduled delivery; -1 = instant
    args: tuple = ()  # sorted ((key, value), …) extras — deterministic

    def arg(self, key: str, default=None):
        for k, v in self.args:
            if k == key:
                return v
        return default


def mkargs(**kw) -> tuple:
    """Canonical ``args`` encoding: key-sorted tuple of pairs, so equal
    payloads are equal records and JSON export is byte-stable."""
    return tuple(sorted(kw.items()))


class TraceBuffer:
    """Bounded ring of :class:`TraceEvent`; drops the oldest on overflow.

    With ``spill_path`` set the oldest records are streamed to a JSONL spool
    file on eviction instead of being discarded: resident memory stays
    bounded at ``cap`` while the spool + ring together hold the complete
    stream (``spilled`` counts spooled records; ``dropped`` stays 0).  The
    spool uses the same line format as :func:`to_jsonl`, so
    :func:`from_jsonl` round-trips it and the auditor can replay the whole
    run (docs/observability.md §3)."""

    def __init__(self, cap: int = 1 << 16, spill_path: str = ""):
        self.cap = int(cap)
        self.spill_path = str(spill_path)
        self._buf: deque[TraceEvent] = (
            deque() if self.spill_path else deque(maxlen=self.cap)
        )
        self.total = 0  # records ever appended
        self.spilled = 0  # records evicted to the spool file
        self._spill_fh = None

    def append(self, ev: TraceEvent) -> None:
        self.total += 1
        self._buf.append(ev)
        if self.spill_path and len(self._buf) > self.cap:
            self._spill(self._buf.popleft())

    def _spill(self, ev: TraceEvent) -> None:
        if self._spill_fh is None:
            self._spill_fh = open(self.spill_path, "w")
        self._spill_fh.write(event_json(ev) + "\n")
        self.spilled += 1

    def flush_spill(self) -> None:
        if self._spill_fh is not None:
            self._spill_fh.flush()

    def spilled_events(self) -> list[TraceEvent]:
        """Re-read the spool: the records evicted so far, oldest first."""
        if not self.spilled:
            return []
        self.flush_spill()
        with open(self.spill_path) as fh:
            return from_jsonl(fh.read())

    @property
    def dropped(self) -> int:
        return self.total - len(self._buf) - self.spilled

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self):
        return iter(self._buf)

    def events(self) -> tuple[TraceEvent, ...]:
        return tuple(self._buf)

    def all_events(self) -> list[TraceEvent]:
        """Spool + resident ring: the complete appended stream (equal to
        ``events()`` when nothing spilled)."""
        return self.spilled_events() + list(self._buf)

    def clear(self) -> None:
        self._buf.clear()
        self.total = 0
        self.spilled = 0
        if self._spill_fh is not None:
            self._spill_fh.close()
            self._spill_fh = None


# ---------------------------------------------------------------------------
# exporters (docs/observability.md §3)
# ---------------------------------------------------------------------------


def _jsonable(v):
    if isinstance(v, (int, float, str, bool)) or v is None:
        return v
    if isinstance(v, (tuple, list)):
        # recursive, so nested arg payloads (pids, peers, groups, wm vectors)
        # survive a JSONL round-trip instead of flattening to repr strings
        return [_jsonable(x) for x in v]
    return repr(v)


def event_json(ev: TraceEvent) -> str:
    """The canonical key-sorted JSON line of one record (shared by
    :func:`to_jsonl` and the :class:`TraceBuffer` spill spool)."""
    d = dataclasses.asdict(ev)
    d["args"] = [[k, _jsonable(v)] for k, v in ev.args]
    for k in ("node", "src", "dst"):
        d[k] = _jsonable(d[k])
    return json.dumps(d, sort_keys=True)


def to_jsonl(events: Iterable[TraceEvent], dropped: int = 0) -> str:
    """One key-sorted JSON object per record, preceded by a meta header.
    Deterministic byte-for-byte for a deterministic run (same-seed runs
    export identical strings — tested in tests/test_obs.py)."""
    lines = [json.dumps({"meta": "holon-trace-v1", "dropped": int(dropped)},
                        sort_keys=True)]
    for ev in events:
        lines.append(event_json(ev))
    return "\n".join(lines) + "\n"


def _untuple(v):
    return tuple(_untuple(x) for x in v) if isinstance(v, list) else v


def from_jsonl(text: str) -> list[TraceEvent]:
    """Parse :func:`to_jsonl` / spill-spool output back into records.

    Inverse of :func:`event_json` for every value the runtimes record (JSON
    scalars and nested tuples; tuples come back as tuples).  Meta header
    lines are skipped, so a full export and a bare spool both parse."""
    out: list[TraceEvent] = []
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        d = json.loads(line)
        if "meta" in d and "kind" not in d:
            continue
        d["args"] = tuple((k, _untuple(v)) for k, v in d["args"])
        out.append(TraceEvent(**d))
    return out


def _pid(endpoint) -> int:
    """Chrome process id for an endpoint: nodes map to their id, the storage
    service to -1, and actor-less records to -2."""
    if endpoint is None:
        return -2
    if isinstance(endpoint, int):
        return endpoint
    return -1  # "storage" (or any non-int service endpoint)


def to_chrome(events: Iterable[TraceEvent]) -> dict:
    """Chrome trace-event JSON (the ``traceEvents`` array format): open a
    chaos run in Perfetto / chrome://tracing as a per-node (process) /
    per-partition (thread) timeline.  Spans (``t_end_ms >= t_ms``) export as
    complete "X" events, point records as instant "i" events; ``ts`` is in
    microseconds per the format."""
    out: list[dict] = []
    seen_pids: set[int] = set()
    for ev in events:
        pid = _pid(ev.node if ev.node is not None else ev.src)
        tid = ev.partition + 1 if ev.partition >= 0 else 0
        seen_pids.add(pid)
        args = {k: _jsonable(v) for k, v in ev.args}
        for k in ("src", "dst"):
            v = getattr(ev, k)
            if v is not None:
                args[k] = _jsonable(v)
        if ev.cls:
            args["cls"] = ev.cls
        if ev.nbytes:
            args["nbytes"] = ev.nbytes
        if ev.status:
            args["status"] = ev.status
        if ev.window >= 0:
            args["window"] = ev.window
        base = {
            "name": ev.kind,
            "cat": ev.kind.split(".", 1)[0],
            "pid": pid,
            "tid": tid,
            "ts": ev.t_ms * 1000.0,
            "args": args,
        }
        if ev.t_end_ms >= ev.t_ms:
            out.append({**base, "ph": "X", "dur": (ev.t_end_ms - ev.t_ms) * 1000.0})
        else:
            out.append({**base, "ph": "i", "s": "p"})
    meta = [
        {
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0, "ts": 0.0,
            "args": {"name": "storage" if pid == -1
                     else ("fabric" if pid == -2 else f"node{pid}")},
        }
        for pid in sorted(seen_pids)
    ]
    return {"traceEvents": meta + out, "displayTimeUnit": "ms"}
