"""Wall-clock vs sim-time measurement, kept in separate types so the two
domains cannot be conflated (docs/observability.md §1).

The discrete-event runtimes live entirely in **simulated** milliseconds
(``Sim.now``): every registry metric and trace record uses sim timestamps,
and nothing in a sim path may read the wall clock (that would break the
same-seed bit-identical guarantee).  Wall-clock timing exists only at the
edges — the real jitted dataplane in launch/stream.py, benchmark drivers —
and goes through :class:`WallTimer`, whose ``domain`` tag follows the
measurement into metric names and benchmark rows.
"""
from __future__ import annotations

import time


class WallTimer:
    """Context-manager stopwatch over the host wall clock (``domain="wall"``).
    The only sanctioned ``time.time()`` in measurement paths — sim code uses
    :class:`SimTimer` (or ``Sim.now`` directly) instead."""

    domain = "wall"

    def __enter__(self) -> "WallTimer":
        self.t0 = time.time()
        self.dt = 0.0  # seconds (live until __exit__ freezes it)
        return self

    def __exit__(self, *exc) -> None:
        self.dt = time.time() - self.t0

    @property
    def dt_ms(self) -> float:
        return self.dt * 1e3


class SimTimer:
    """Context-manager stopwatch over a simulator clock (``domain="sim"``).
    ``dt`` is simulated seconds — deliberately the same attribute shape as
    :class:`WallTimer` so call sites swap domains without reshaping, but a
    distinct type so a reader (or grep) always knows which clock a number
    came from."""

    domain = "sim"

    def __init__(self, sim):
        self.sim = sim

    def __enter__(self) -> "SimTimer":
        self.t0 = self.sim.now
        self.dt = 0.0
        return self

    def __exit__(self, *exc) -> None:
        self.dt = (self.sim.now - self.t0) / 1e3  # sim ms -> "seconds"

    @property
    def dt_ms(self) -> float:
        return self.dt * 1e3
