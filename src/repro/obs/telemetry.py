"""Telemetry facade: one object carrying a deployment's trace buffer and
metrics registry through both runtimes (docs/observability.md §1).

A :class:`Telemetry` is created per harness from ``SimConfig`` and threaded
into the network fabric, checkpoint storage, consumer, and every node — the
single owner of the bounded :class:`~repro.obs.records.TraceBuffer` and the
:class:`~repro.obs.registry.MetricsRegistry`, so net records and protocol
spans land in ONE time-ordered stream the auditor can replay.

Two independent switches:

* ``trace_net`` (``SimConfig.net_trace`` or ``obs``) — record one
  ``net.msg`` per fabric send attempt;
* ``on`` (``SimConfig.obs``) — record protocol spans/events and registry
  metrics (implies net records: the auditor's ack cross-check needs them).

Both default off.  Recording never draws RNG, never reads the wall clock,
and never schedules simulator events, so with telemetry off the runtimes are
bit-identical to a build without this module, and with it on the same seed
exports byte-identical traces (tests/test_obs.py).
"""
from __future__ import annotations

from repro.obs.records import TraceBuffer, TraceEvent, mkargs, to_chrome, to_jsonl
from repro.obs.registry import MetricsRegistry


class Telemetry:
    __slots__ = ("sim", "on", "trace_net", "buf", "registry", "snapshot_ms",
                 "_subs")

    def __init__(self, sim, on: bool = False, trace_net: bool = False,
                 cap: int = 1 << 16, snapshot_ms: float = 500.0,
                 spill_path: str = ""):
        self.sim = sim
        self.on = bool(on)
        self.trace_net = bool(trace_net) or self.on
        self.buf = TraceBuffer(cap, spill_path=spill_path)
        self.registry = MetricsRegistry()
        self.snapshot_ms = float(snapshot_ms)
        # passive subscribers (obs/monitor.py): each appended record is also
        # handed to every subscriber, in append order.  Subscribers must be
        # passive too — no RNG, no sim events — so subscribing cannot perturb
        # the run; with none registered the append path is unchanged.
        self._subs: tuple = ()

    @classmethod
    def from_config(cls, sim, cfg) -> "Telemetry":
        """The one place SimConfig's obs knobs become a telemetry instance —
        both runtimes build theirs here, mirroring NetworkFabric.from_config.
        ``obs_monitor`` implies ``obs``: the online monitor consumes the
        record stream, so enabling it turns recording on."""
        return cls(
            sim,
            on=cfg.obs or getattr(cfg, "obs_monitor", False),
            trace_net=cfg.net_trace,
            cap=cfg.obs_trace_cap,
            snapshot_ms=cfg.obs_snapshot_ms,
            spill_path=getattr(cfg, "obs_spill_path", ""),
        )

    # ---- subscription ------------------------------------------------------
    def subscribe(self, fn) -> None:
        """Register ``fn(event)`` to observe every appended record (net and
        protocol), in append order — the online monitor's feed."""
        self._subs = self._subs + (fn,)

    def unsubscribe(self, fn) -> None:
        # equality, not identity: a bound method like ``monitor.feed`` is a
        # fresh object on every attribute access, but compares equal
        self._subs = tuple(f for f in self._subs if f != fn)

    # ---- recording ---------------------------------------------------------
    def net_msg(self, src, dst, cls: str, nbytes: float, status: str,
                t_deliver: float = -1.0, retries: int = 0) -> None:
        if self.trace_net:
            ev = TraceEvent(
                t_ms=self.sim.now, kind="net.msg", src=src, dst=dst, cls=cls,
                nbytes=nbytes, status=status, t_end_ms=t_deliver,
                args=(("retries", retries),) if retries else (),
            )
            self.buf.append(ev)
            if self._subs:
                for fn in self._subs:
                    fn(ev)

    def event(self, kind: str, node=None, partition: int = -1,
              window: int = -1, src=None, dst=None, status: str = "",
              t_end_ms: float = -1.0, **args) -> None:
        """Protocol span/event (gated on ``on``; call sites in hot paths
        guard with ``if obs.on`` themselves to skip building kwargs)."""
        if self.on:
            ev = TraceEvent(
                t_ms=self.sim.now, kind=kind, node=node, partition=partition,
                window=window, src=src, dst=dst, status=status,
                t_end_ms=t_end_ms, args=mkargs(**args) if args else (),
            )
            self.buf.append(ev)
            if self._subs:
                for fn in self._subs:
                    fn(ev)

    # ---- scheduling --------------------------------------------------------
    def start_snapshots(self) -> None:
        """Periodic registry snapshots on sim-time (no-op when ``on`` is
        False).  Snapshots only read state — they cannot affect the run."""
        if not self.on:
            return

        def snap():
            self.registry.snapshot(self.sim.now)
            self.sim.after(self.snapshot_ms, snap)

        self.sim.after(self.snapshot_ms, snap)

    # ---- access / export ---------------------------------------------------
    def events(self) -> tuple[TraceEvent, ...]:
        return self.buf.events()

    def all_events(self) -> list[TraceEvent]:
        """Spill spool + resident ring: the complete record stream (equal to
        ``events()`` when no spill is configured or nothing spilled)."""
        return self.buf.all_events()

    def net_events(self) -> list[TraceEvent]:
        return [ev for ev in self.buf if ev.kind == "net.msg"]

    def export_jsonl(self) -> str:
        return to_jsonl(self.buf, dropped=self.buf.dropped)

    def export_chrome(self) -> dict:
        return to_chrome(self.buf)
