"""Telemetry facade: one object carrying a deployment's trace buffer and
metrics registry through both runtimes (docs/observability.md §1).

A :class:`Telemetry` is created per harness from ``SimConfig`` and threaded
into the network fabric, checkpoint storage, consumer, and every node — the
single owner of the bounded :class:`~repro.obs.records.TraceBuffer` and the
:class:`~repro.obs.registry.MetricsRegistry`, so net records and protocol
spans land in ONE time-ordered stream the auditor can replay.

Two independent switches:

* ``trace_net`` (``SimConfig.net_trace`` or ``obs``) — record one
  ``net.msg`` per fabric send attempt;
* ``on`` (``SimConfig.obs``) — record protocol spans/events and registry
  metrics (implies net records: the auditor's ack cross-check needs them).

Both default off.  Recording never draws RNG, never reads the wall clock,
and never schedules simulator events, so with telemetry off the runtimes are
bit-identical to a build without this module, and with it on the same seed
exports byte-identical traces (tests/test_obs.py).
"""
from __future__ import annotations

from repro.obs.records import TraceBuffer, TraceEvent, mkargs, to_chrome, to_jsonl
from repro.obs.registry import MetricsRegistry


class Telemetry:
    __slots__ = ("sim", "on", "trace_net", "buf", "registry", "snapshot_ms")

    def __init__(self, sim, on: bool = False, trace_net: bool = False,
                 cap: int = 1 << 16, snapshot_ms: float = 500.0):
        self.sim = sim
        self.on = bool(on)
        self.trace_net = bool(trace_net) or self.on
        self.buf = TraceBuffer(cap)
        self.registry = MetricsRegistry()
        self.snapshot_ms = float(snapshot_ms)

    @classmethod
    def from_config(cls, sim, cfg) -> "Telemetry":
        """The one place SimConfig's obs knobs become a telemetry instance —
        both runtimes build theirs here, mirroring NetworkFabric.from_config."""
        return cls(
            sim,
            on=cfg.obs,
            trace_net=cfg.net_trace,
            cap=cfg.obs_trace_cap,
            snapshot_ms=cfg.obs_snapshot_ms,
        )

    # ---- recording ---------------------------------------------------------
    def net_msg(self, src, dst, cls: str, nbytes: float, status: str,
                t_deliver: float = -1.0) -> None:
        if self.trace_net:
            self.buf.append(TraceEvent(
                t_ms=self.sim.now, kind="net.msg", src=src, dst=dst, cls=cls,
                nbytes=nbytes, status=status, t_end_ms=t_deliver,
            ))

    def event(self, kind: str, node=None, partition: int = -1,
              window: int = -1, src=None, dst=None, status: str = "",
              t_end_ms: float = -1.0, **args) -> None:
        """Protocol span/event (gated on ``on``; call sites in hot paths
        guard with ``if obs.on`` themselves to skip building kwargs)."""
        if self.on:
            self.buf.append(TraceEvent(
                t_ms=self.sim.now, kind=kind, node=node, partition=partition,
                window=window, src=src, dst=dst, status=status,
                t_end_ms=t_end_ms, args=mkargs(**args) if args else (),
            ))

    # ---- scheduling --------------------------------------------------------
    def start_snapshots(self) -> None:
        """Periodic registry snapshots on sim-time (no-op when ``on`` is
        False).  Snapshots only read state — they cannot affect the run."""
        if not self.on:
            return

        def snap():
            self.registry.snapshot(self.sim.now)
            self.sim.after(self.snapshot_ms, snap)

        self.sim.after(self.snapshot_ms, snap)

    # ---- access / export ---------------------------------------------------
    def events(self) -> tuple[TraceEvent, ...]:
        return self.buf.events()

    def net_events(self) -> list[TraceEvent]:
        return [ev for ev in self.buf if ev.kind == "net.msg"]

    def export_jsonl(self) -> str:
        return to_jsonl(self.buf, dropped=self.buf.dropped)

    def export_chrome(self) -> dict:
        return to_chrome(self.buf)
