"""Online protocol monitor (docs/observability.md §6).

The post-hoc auditor (obs/audit.py) certifies a finished trace; the
:class:`OnlineMonitor` runs the same invariant checks *while the run is in
flight*, in bounded memory, by subscribing to the telemetry append stream
(``Telemetry.subscribe``).  It is strictly passive: it draws no randomness,
schedules no simulator events, and touches nothing the runtimes read — a run
with the monitor on is byte-identical to the same seed with it off
(A/B-tested in tests/test_monitor.py).

Alerts come in two severities:

* ``violation`` — protocol invariants, same ids as the auditor so the two
  can be diffed one-to-one (equivalence-tested on every tier-1 scenario
  family):

  - ``[exactly-once]``   duplicate/conflicting emission of a (pid, wid);
  - ``[frontier-regression]`` a checkpoint apply regressed the stored
    ``nxt_idx`` frontier;
  - ``[domination]``     a merged delta was not dominated (or a nack was);
  - ``[unacked-merge]``  a merge was applied but never acknowledged.

* ``warn`` — operational health, thresholds from ``SimConfig``:

  - ``[frontier-stall]`` no fold or emission progressed for
    ``obs_stall_ms`` of sim time (stuck pipeline / dead quorum);
  - ``[straggler]``      one node persistently *originates* the critical
    path of other nodes' emissions (its folds arrive last and gate
    everyone — the causal signature of a degraded peer);
  - ``[sync-burn]``      sync-plane bytes/sec exceeded
    ``obs_sync_budget`` over a 1 s bucket;
  - ``[slo-burn]``       more than ``obs_slo_frac`` of recent emissions
    missed the ``obs_slo_ms`` latency SLO.

State is bounded: recent-window dedup maps, fixed-depth deques, and the
:class:`~repro.obs.critpath.WatermarkTracker`'s O(nodes x partitions) lane
maps.  Alerts are capped (oldest kept) with a drop counter.
"""
from __future__ import annotations

import dataclasses
from collections import Counter, deque

from repro.obs.critpath import WatermarkTracker
from repro.obs.records import TraceEvent

#: invariant ids shared verbatim with the post-hoc auditor — the
#: monitor/auditor equivalence tests compare violation sets over these
AUDIT_IDS = ("exactly-once", "frontier-regression", "domination",
             "unacked-merge")

_ALERT_CAP = 1024  # alerts kept (oldest first; overflow counted)
_RECENT_WINDOWS = 1 << 14  # (pid, wid) emission memory for exactly-once
_ORIGIN_WINDOW = 64  # emissions per straggler vote
_SLO_WINDOW = 32  # emissions per SLO burn vote
_BURN_BUCKET_MS = 1000.0  # sync-burn accounting bucket
# record kinds the invariant/health state actually reads; anything else is
# clock-only for the monitor (see the fast path in ``feed``)
_FEED_KINDS = frozenset((
    "emit", "sync.recv", "ckpt.apply", "net.msg", "exec.batch",
    "steal.adopt", "node.restart",
))


@dataclasses.dataclass(frozen=True)
class Alert:
    t_ms: float
    id: str  # catalog id, e.g. "exactly-once", "frontier-stall"
    severity: str  # "violation" | "warn"
    msg: str

    def __str__(self) -> str:
        return f"[{self.id}] t={self.t_ms:.1f} {self.msg}"


class OnlineMonitor:
    """Incremental protocol auditor over the live telemetry stream."""

    def __init__(self, num_partitions: int = 0, stall_ms: float = 5000.0,
                 slo_ms: float = 0.0, slo_frac: float = 0.5,
                 sync_budget: float = 0.0, straggler_frac: float = 0.5):
        self.stall_ms = float(stall_ms)
        self.slo_ms = float(slo_ms)
        self.slo_frac = float(slo_frac)
        self.sync_budget = float(sync_budget)
        self.straggler_frac = float(straggler_frac)
        self.alerts: deque[Alert] = deque(maxlen=_ALERT_CAP)
        self.alerts_dropped = 0
        self.fed = 0
        # --- invariant state (mirrors obs/audit.py, windowed) ---
        self._digests: dict = {}  # (pid, wid) -> digest of accepted emit
        self._digest_order: deque = deque()
        self._frontier: dict = {}  # pid -> max applied nxt_idx
        self._unacked: list = []  # (t, node, src) merges awaiting ack
        self._acks: Counter = Counter()  # (t, from, to) ack sends seen
        # --- health state ---
        self.tracker = WatermarkTracker(num_partitions=num_partitions)
        self._last_progress = None  # t of last fold/emit, None before first
        self._stalled = False
        self._origins: deque = deque(maxlen=_ORIGIN_WINDOW)
        self._lat: deque = deque(maxlen=_SLO_WINDOW)
        self._slo_hot = False
        self._bucket = 0  # current sync-burn bucket index
        self._bucket_bytes = 0.0
        self._burn_hot = False

    @classmethod
    def from_config(cls, cfg) -> "OnlineMonitor":
        return cls(
            num_partitions=cfg.num_partitions,
            stall_ms=getattr(cfg, "obs_stall_ms", 5000.0),
            slo_ms=getattr(cfg, "obs_slo_ms", 0.0),
            slo_frac=getattr(cfg, "obs_slo_frac", 0.5),
            sync_budget=getattr(cfg, "obs_sync_budget", 0.0),
        )

    def attach(self, telemetry) -> None:
        telemetry.subscribe(self.feed)

    # ------------------------------------------------------------------
    def _alert(self, t_ms: float, id: str, severity: str, msg: str) -> None:
        if len(self.alerts) == self.alerts.maxlen:
            self.alerts_dropped += 1
        self.alerts.append(Alert(t_ms=t_ms, id=id, severity=severity, msg=msg))

    def violations(self) -> list[Alert]:
        self._settle(float("inf"))
        return [a for a in self.alerts if a.severity == "violation"]

    def violation_ids(self) -> set:
        return {a.id for a in self.violations()}

    def warning_ids(self) -> set:
        return {a.id for a in self.alerts if a.severity == "warn"}

    def finish(self) -> None:
        """Flush end-of-run state (pending unacked merges)."""
        self._settle(float("inf"))

    # ------------------------------------------------------------------
    def feed(self, ev: TraceEvent) -> None:
        self.fed += 1
        t = ev.t_ms
        kind = ev.kind
        if kind not in _FEED_KINDS:
            # hot fast path: most records (spans, heartbeats, shuffle hops)
            # carry nothing the invariants read — they only advance the
            # clock for ack settlement and the stall detector.  This keeps
            # the monitor's overhead inside the documented budget on the
            # baseline's record mix too.
            if self._unacked:
                self._settle(t)
            lp = self._last_progress
            if (lp is not None and not self._stalled
                    and t - lp > self.stall_ms):
                self._stalled = True
                self._alert(t, "frontier-stall", "warn",
                            f"no fold/emission progress for {t - lp:.0f} ms")
            return
        if self._unacked:
            self._settle(t)
        self._check_stall(ev)
        if kind != "net.msg" or ev.cls == "sync":
            self.tracker.feed(ev)
        if kind == "emit":
            self._on_emit(ev)
        elif kind == "sync.recv":
            # merge applied with ack-expected marker: an ack send must show
            # up at the same instant (the receiver replies in the same
            # callback) — settled when sim time first advances past t
            if (ev.status in ("delta_merge", "full_merge")
                    and ev.arg("marker", 0)):
                self._unacked.append((t, ev.node, ev.src))
        elif kind == "ckpt.apply":
            nxt = int(ev.arg("nxt_idx", 0))
            prev = self._frontier.get(ev.partition)
            if prev is not None and nxt < prev:
                self._alert(
                    t, "frontier-regression", "violation",
                    f"p{ev.partition} applied nxt_idx {nxt} < {prev}")
            self._frontier[ev.partition] = max(
                nxt, prev if prev is not None else nxt)
        elif kind == "net.msg":
            if ev.cls == "sync_ack":
                # the *send attempt* acknowledges — delivery may be lossy
                self._acks[(t, ev.src, ev.dst)] += 1
            if ev.cls.startswith("sync"):
                self._account_sync(t, ev.nbytes)
        if kind == "sync.recv":
            dominated = bool(ev.arg("dominated", 1))
            if ev.status == "delta_merge" and not dominated:
                self._alert(t, "domination", "violation",
                            f"node {ev.node} merged a non-dominated delta "
                            f"from {ev.src}")
            elif ev.status == "nack" and dominated:
                self._alert(t, "domination", "violation",
                            f"node {ev.node} nacked a dominated delta "
                            f"from {ev.src}")

    # ------------------------------------------------------------------
    def _settle(self, now: float) -> None:
        """Match merges against same-instant acks once time moves on."""
        if not self._unacked:
            if now == float("inf"):
                self._acks.clear()
            return
        keep = []
        for (t, node, src) in self._unacked:
            if t >= now:
                keep.append((t, node, src))
                continue
            key = (t, node, src)  # ack goes merge-node -> delta sender
            if self._acks[key] > 0:
                self._acks[key] -= 1
            else:
                self._alert(t, "unacked-merge", "violation",
                            f"merge at node {node} from {src} never acked")
        self._unacked = keep
        if not keep:
            self._acks.clear()

    def _check_stall(self, ev: TraceEvent) -> None:
        progressed = (ev.kind == "exec.batch"
                      or (ev.kind == "emit" and ev.status == "accepted"))
        if self._last_progress is None:
            if progressed:
                self._last_progress = ev.t_ms
            return
        gap = ev.t_ms - self._last_progress
        if gap > self.stall_ms and not self._stalled:
            self._stalled = True
            self._alert(ev.t_ms, "frontier-stall", "warn",
                        f"no fold/emission progress for {gap:.0f} ms")
        if progressed:
            self._last_progress = ev.t_ms
            self._stalled = False

    def _on_emit(self, ev: TraceEvent) -> None:
        pid, wid, t = ev.partition, ev.window, ev.t_ms
        key = (pid, wid)
        digest = ev.arg("digest")
        if ev.status == "accepted":
            if key in self._digests:
                self._alert(t, "exactly-once", "violation",
                            f"window (p{pid}, w{wid}) accepted twice")
            else:
                self._digests[key] = digest
                self._digest_order.append(key)
                if len(self._digest_order) > _RECENT_WINDOWS:
                    self._digests.pop(self._digest_order.popleft(), None)
            # health votes ride accepted emissions only
            self._vote_slo(t, float(ev.arg("latency_ms", 0.0)))
            self._vote_straggler(t, ev)
        elif ev.status == "duplicate":
            if key not in self._digests:
                self._alert(t, "exactly-once", "violation",
                            f"window (p{pid}, w{wid}) deduped before any "
                            f"accepted emission")
            elif digest != self._digests[key]:
                self._alert(t, "exactly-once", "violation",
                            f"window (p{pid}, w{wid}) re-emitted with a "
                            f"different digest")

    def _vote_slo(self, t: float, latency_ms: float) -> None:
        if self.slo_ms <= 0:
            return
        self._lat.append(latency_ms)
        if len(self._lat) < self._lat.maxlen:
            return
        frac = sum(1 for x in self._lat if x > self.slo_ms) / len(self._lat)
        if frac > self.slo_frac:
            if not self._slo_hot:
                self._slo_hot = True
                self._alert(t, "slo-burn", "warn",
                            f"{frac:.0%} of last {len(self._lat)} emissions "
                            f"over the {self.slo_ms:.0f} ms SLO")
        else:
            self._slo_hot = False

    def _vote_straggler(self, t: float, ev: TraceEvent) -> None:
        _, _, elem = self.tracker.binding(ev.node, ev.partition)
        origin = elem.root().node
        if origin is None:
            return
        self._origins.append((origin, ev.node))
        if len(self._origins) < self._origins.maxlen:
            return
        # a *remote* origin persistently gating emissions = straggler peer
        remote = Counter(o for o, n in self._origins if o != n)
        if remote:
            node, cnt = remote.most_common(1)[0]
            if cnt / len(self._origins) >= self.straggler_frac:
                self._alert(t, "straggler", "warn",
                            f"node {node} originates {cnt}/"
                            f"{len(self._origins)} recent critical paths")
                self._origins.clear()

    def _account_sync(self, t: float, nbytes: float) -> None:
        if self.sync_budget <= 0:
            return
        bucket = int(t // _BURN_BUCKET_MS)
        if bucket != self._bucket:
            self._close_bucket()
            self._bucket, self._bucket_bytes = bucket, 0.0
        self._bucket_bytes += nbytes

    def _close_bucket(self) -> None:
        rate = self._bucket_bytes / (_BURN_BUCKET_MS / 1000.0)
        if rate > self.sync_budget:
            if not self._burn_hot:
                self._burn_hot = True
                self._alert(self._bucket * _BURN_BUCKET_MS, "sync-burn",
                            "warn",
                            f"sync plane burned {rate:.0f} B/s against a "
                            f"{self.sync_budget:.0f} B/s budget")
        else:
            self._burn_hot = False


def replay(events, cfg=None) -> OnlineMonitor:
    """Feed a recorded stream through a fresh monitor (testing/offline use:
    the monitor/auditor equivalence tests replay mutated traces this way)."""
    mon = OnlineMonitor.from_config(cfg) if cfg is not None else OnlineMonitor()
    for ev in events:
        mon.feed(ev)
    mon.finish()
    return mon
