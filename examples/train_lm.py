"""End-to-end driver: train a ~100M-param LM for a few hundred steps with the
paper's runtime features live — WCRDT metric windows (deterministic global
aggregation without barriers), decentralized checkpoints, crash recovery.

Quick demo (tiny model, ~2 min):
    PYTHONPATH=src python examples/train_lm.py
Full run (100M params, a few hundred steps — hours on CPU, minutes on TPU):
    PYTHONPATH=src python examples/train_lm.py --full
"""
import argparse


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="100M-param preset instead of the tiny demo")
    args = ap.parse_args(argv)

    from repro.launch.train import main as train_main

    train_main(
        ["--preset", "100m", "--steps", "300", "--batch", "8", "--seq", "512"]
        if args.full
        else ["--preset", "tiny", "--steps", "40", "--crash-at", "25",
              "--ckpt-every", "10", "--ckpt-dir", "/tmp/repro_example_ckpt"]
    )


if __name__ == "__main__":
    main()
