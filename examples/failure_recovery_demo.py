"""Figure-6-style demo: inject two concurrent node failures and watch the
decentralized runtime steal the orphaned partitions and catch up, vs the
centralized baseline's global stop-restore-replay.

The network fabric (docs/protocol.md §4) can misbehave too:

  --loss 0.1           drop 10% of gossip/shuffle messages
  --partition 8000:16000   split the cluster in half for that window

Run: PYTHONPATH=src python examples/failure_recovery_demo.py
     PYTHONPATH=src python examples/failure_recovery_demo.py --loss 0.1
     PYTHONPATH=src python examples/failure_recovery_demo.py \
         --no-crash --partition 8000:16000
"""
import argparse
import dataclasses


def parse_partition(spec: str) -> tuple[float, float]:
    try:
        t0, t1 = (float(x) for x in spec.split(":"))
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"--partition wants 'T0:T1' in ms, got {spec!r}"
        ) from None
    if not t0 < t1:
        raise argparse.ArgumentTypeError("--partition needs T0 < T1")
    return t0, t1


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batches", type=int, default=300)
    ap.add_argument("--loss", type=float, default=0.0,
                    help="gossip/shuffle message-loss probability (0..1)")
    ap.add_argument("--partition", type=parse_partition, default=None,
                    metavar="T0:T1",
                    help="2-way network split from T0 to T1 (simulated ms)")
    ap.add_argument("--no-crash", action="store_true",
                    help="skip the node crashes (fabric faults only)")
    args = ap.parse_args(argv)

    from repro.runtime import FailureScenario, SimConfig, as_scenario, run_flink, run_holon
    from repro.streaming import make_q7

    cfg = SimConfig(num_batches=args.batches, net_loss=args.loss)
    q = make_q7(cfg.num_partitions, window_len=cfg.window_len, num_slots=cfg.num_slots)

    scen = as_scenario(None if args.no_crash else FailureScenario.concurrent(t=8000.0))
    scen = dataclasses.replace(scen, name="chaos-demo")
    what = [] if args.no_crash else ["two nodes fail at t=8s, restart at t=18s"]
    if args.partition:
        t0, t1 = args.partition
        members = cfg.initial_membership
        half = len(members) // 2
        scen = scen.partition(t0, members[:half], members[half:]).heal(t1)
        what.append(f"2-way partition {t0 / 1e3:g}s..{t1 / 1e3:g}s")
    if args.loss:
        what.append(f"{args.loss:.0%} message loss")
    print("; ".join(what) or "failure-free baseline", "\n")

    for name, runner in (("HOLON (decentralized)", run_holon),
                         ("FLINK-like (centralized)", run_flink)):
        c = runner(cfg, q, scen, horizon_ms=cfg.horizon_ms + 20_000)
        t, lat = c.latency_series()
        print(f"--- {name} ---")
        for lo in range(0, 32000, 4000):
            m = (t >= lo) & (t < lo + 4000)
            if m.sum():
                bar = "#" * min(60, int(lat[m].mean() / 50))
                print(f"  t={lo//1000:3d}-{lo//1000+4:<3d}s avg={lat[m].mean():7.0f} ms {bar}")
        s = c.latency_stats()
        dropped = sum(st["dropped"] for st in c.net_stats.values())
        print(f"  avg={s['avg']:.0f} ms  p99={s['p99']:.0f} ms  "
              f"dropped_msgs={dropped}\n")


if __name__ == "__main__":
    main()
