"""Figure-6-style demo: inject two concurrent node failures and watch the
decentralized runtime steal the orphaned partitions and catch up, vs the
centralized baseline's global stop-restore-replay.

Run: PYTHONPATH=src python examples/failure_recovery_demo.py
"""
import argparse


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batches", type=int, default=300)
    args = ap.parse_args(argv)

    from repro.runtime import FailureScenario, SimConfig, run_flink, run_holon
    from repro.streaming import make_q7

    cfg = SimConfig(num_batches=args.batches)
    q = make_q7(cfg.num_partitions, window_len=cfg.window_len, num_slots=cfg.num_slots)
    scen = FailureScenario.concurrent(t=8000.0)
    print("two nodes fail at t=8s, restart at t=18s\n")

    for name, runner in (("HOLON (decentralized)", run_holon),
                         ("FLINK-like (centralized)", run_flink)):
        c = runner(cfg, q, scen, horizon_ms=cfg.horizon_ms + 20_000)
        t, lat = c.latency_series()
        print(f"--- {name} ---")
        for lo in range(0, 32000, 4000):
            m = (t >= lo) & (t < lo + 4000)
            if m.sum():
                bar = "#" * min(60, int(lat[m].mean() / 50))
                print(f"  t={lo//1000:3d}-{lo//1000+4:<3d}s avg={lat[m].mean():7.0f} ms {bar}")
        s = c.latency_stats()
        print(f"  avg={s['avg']:.0f} ms  p99={s['p99']:.0f} ms\n")


if __name__ == "__main__":
    main()
