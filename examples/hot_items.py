"""Nexmark Q5 "hot items" over an overlapping sliding window — the workload
tumbling windows cannot express (a burst straddling a window edge is split
and missed; the hopping window sees it whole).

Runs the same query on BOTH deployment paths and checks them against the
plain-jnp oracle:

  * the discrete-event Holon runtime (decentralized coordination), and
  * the shard_map dataplane driver (the TPU-native path, here on CPU),

then prints the hottest auction bucket per sliding window.

Run: PYTHONPATH=src python examples/hot_items.py
"""
import argparse


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batches", type=int, default=100)
    ap.add_argument("--window-len", type=int, default=1000)
    ap.add_argument("--hop", type=int, default=500,
                    help="window start spacing; each event lives in "
                         "window_len/hop overlapping windows")
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from repro import compat
    from repro.launch.stream import build_pipeline, read_window_range
    from repro.runtime import SimConfig, run_holon
    from repro.streaming import NexmarkConfig, generate_log, make_q5

    cfg = SimConfig(num_nodes=3, num_partitions=6, num_batches=args.batches,
                    window_len=args.window_len)
    q = make_q5(cfg.num_partitions, window_len=args.window_len,
                num_slots=cfg.num_slots, hop=args.hop)
    a = q.assigner
    print(f"Q5 hot items: window={a.window_len} hop={a.hop} "
          f"({a.windows_per_event} windows per event)")

    # --- discrete-event runtime ------------------------------------------
    consumer = run_holon(cfg, q)
    nx = NexmarkConfig(num_partitions=cfg.num_partitions, num_batches=cfg.num_batches,
                       events_per_batch=cfg.events_per_batch,
                       rate_per_partition=cfg.rate_per_partition, seed=cfg.seed)
    log = generate_log(nx)
    wids = sorted({w for (_, w) in consumer.records})
    oracle = {w: np.asarray(q.oracle(log, w)) for w in wids}  # one eval per wid
    for (pid, w), rec in sorted(consumer.records.items()):
        np.testing.assert_array_equal(np.asarray(rec.value), oracle[w])
    print(f"runtime: {len(consumer.records)} window emissions across "
          f"{len(wids)} sliding windows — all byte-identical to the oracle")
    for w in wids[:5]:
        count, bucket = consumer.records[(0, w)].value
        print(f"  window [{a.start_ts(w)}, {a.end_ts(w)}): "
              f"hottest auction bucket {int(bucket)} with {int(count)} bids")

    # --- shard_map dataplane ---------------------------------------------
    n_dev = len(jax.devices())
    mesh = compat.make_mesh((n_dev,), ("data",))
    dnx = NexmarkConfig(num_partitions=n_dev, num_batches=32, events_per_batch=1024)
    dlog = generate_log(dnx)
    dq = make_q5(n_dev, window_len=args.window_len, num_slots=64, hop=args.hop)
    first, n_windows = read_window_range(dq, 32 * dnx.batch_span_ms)
    with mesh:
        oks, vals, sync_bytes = build_pipeline(dq, mesh, sync_every=4,
                                               n_windows=n_windows,
                                               first_window=first)(dlog)
    oks, vals = np.asarray(oks), np.asarray(vals)
    done = int(oks[0].sum())
    for i in range(n_windows):
        if oks[0, i]:
            np.testing.assert_array_equal(
                vals[0, i], np.asarray(dq.oracle(dlog, first + i))
            )
    print(f"dataplane: {done} complete sliding windows on {n_dev} device(s), "
          f"byte-identical to the oracle; "
          f"sync bytes/device = {float(np.asarray(sync_bytes).sum()):.0f}")


if __name__ == "__main__":
    main()
