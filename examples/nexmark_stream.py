"""Run the Nexmark Q7 (highest bids) global aggregation on the decentralized
Holon runtime, verify outputs against the oracle, and print latency stats.

Run: PYTHONPATH=src python examples/nexmark_stream.py
"""
import argparse


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batches", type=int, default=150)
    args = ap.parse_args(argv)

    import numpy as np

    from repro.runtime import SimConfig, run_holon
    from repro.streaming import NexmarkConfig, generate_log, make_q7

    cfg = SimConfig(num_nodes=5, num_partitions=10, num_batches=args.batches)
    q = make_q7(cfg.num_partitions, window_len=cfg.window_len, num_slots=cfg.num_slots)

    print(f"running Q7 on {cfg.num_nodes} nodes / {cfg.num_partitions} partitions ...")
    consumer = run_holon(cfg, q)
    stats = consumer.latency_stats()
    print(f"windows emitted: {stats['n']}  avg latency: {stats['avg']:.0f} ms  "
          f"p99: {stats['p99']:.0f} ms")

    # verify a few windows against the global oracle
    nx = NexmarkConfig(num_partitions=cfg.num_partitions, num_batches=cfg.num_batches,
                       events_per_batch=cfg.events_per_batch,
                       rate_per_partition=cfg.rate_per_partition, seed=cfg.seed)
    log = generate_log(nx)
    emitted = sorted({w for (p, w) in consumer.records if p == 0})
    assert emitted, "run too short to complete any window; raise --batches"
    checked = [w for w in (0, 3, 7) if w in emitted] or emitted[:1]
    for w in checked:
        rec = consumer.records[(0, w)]
        ov, oi = q.oracle(log, w)
        ok = np.allclose(rec.value[:8], np.asarray(ov), rtol=1e-5)
        top = ", ".join(f"{v:.0f}" for v in np.asarray(ov)[:3])
        print(f"window {w}: top bids [{top} ...]  oracle match: {ok}")
        assert ok
    print("exactly-once outputs verified against the oracle")


if __name__ == "__main__":
    main()
