"""Quickstart: the paper's running example (Query 1, Listing 2).

Ratio of bids processed per partition relative to the GLOBAL number of bids,
per tumbling window — a global aggregation with NO shuffle: a Windowed
GCounter replica per partition, lattice merges as "background sync", reads
gated by the global watermark so every partition emits the SAME ratio
denominator deterministically.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import argparse

P = 3            # partitions
WINDOW = 10      # tumbling window length (timestamp units)


def main(argv=None):
    argparse.ArgumentParser(description=__doc__).parse_args(argv)
    import jax.numpy as jnp

    from repro.core import wcrdt as W
    from repro.core import wgcounter

    # totalCount = WCRDT { zero: GCounter }      (Listing 2, line 2)
    spec = wgcounter(window_len=WINDOW, num_slots=8, num_partitions=P)

    # each partition processes its own bid stream into its own replica
    replicas, local_counts, events = [], [], {
        0: [1, 4, 8, 13, 17, 22],
        1: [2, 5, 11, 14, 21],
        2: [3, 9, 12, 19, 23],
    }
    for p in range(P):
        ts = jnp.array(events[p], jnp.int32)
        s = spec.zero()
        s = W.insert(spec, s, p, ts, jnp.ones(len(events[p]), bool),
                     actor=p, amounts=jnp.ones(len(events[p])))      # insert(1, ts)
        s = W.increment_watermark(spec, s, p, int(ts.max()))         # incrementWatermark
        replicas.append(s)
        local_counts.append({w: sum(1 for t in events[p] if w*WINDOW <= t < (w+1)*WINDOW)
                             for w in range(3)})

    # background sync: lattice merges in ANY order converge (CRDT!)
    merged = replicas[0]
    for s in replicas[1:]:
        merged = W.merge(spec, merged, s)

    gwm = int(W.global_watermark(spec, merged))
    print(f"global watermark = {gwm}")
    for w in range(3):
        total, ok = W.window_value(spec, merged, w)                  # getWindowValue
        if not bool(ok):
            print(f"window {w}: not complete yet (safe mode would block)")
            continue
        print(f"window {w}: global bids = {float(total):.0f}")
        for p in range(P):
            ratio = local_counts[p][w] / float(total)
            print(f"  partition {p}: localCount/total = {ratio:.3f}")  # emit <ratio>


if __name__ == "__main__":
    main()
