#!/usr/bin/env python
"""Docstring↔docs consistency gate (tier-1-adjacent, run by scripts/test.sh).

Scans every .py file under src/, tests/, benchmarks/, examples/, scripts/
for citations of repo markdown files, optionally with a section marker:

    docs/protocol.md §3.2      DESIGN.md §6      README.md

and asserts (1) the cited file exists, and (2) when a section is given, the
file actually contains that `§N` marker (as its own token — `§3` is not
satisfied by `§3.2` alone, but `§3.2` cites are checked verbatim).  This is
what keeps "see docs/... §X" in docstrings from silently rotting.

Exit 0 when every citation resolves; exit 1 with a listing otherwise.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SCAN_DIRS = ("src", "tests", "benchmarks", "examples", "scripts")

# a repo-relative markdown path, optionally followed by "§<sec>"; sections
# are dot/hyphen-joined word tokens ("3", "3.2", "Perf", "Dry-run") — a
# trailing sentence "." is not part of the section
CITE = re.compile(
    r"(?P<file>(?:docs/[\w./-]+\.md|(?:DESIGN|README|ROADMAP|PAPER|PAPERS|"
    r"SNIPPETS|CHANGES|EXPERIMENTS)\.md))(?:\s*§(?P<sec>\w+(?:[-.]\w+)*))?"
)


def section_present(text: str, sec: str) -> bool:
    # token match: "§3" must appear not immediately extended by ".x" or more
    # digits (so citing §3 requires a real §3, not just §3.2 / §30)
    return re.search(rf"§{re.escape(sec)}(?![\w.-])", text) is not None


def main() -> int:
    md_cache: dict[str, str | None] = {}
    failures: list[str] = []
    n_citations = 0
    for d in SCAN_DIRS:
        for py in sorted((ROOT / d).rglob("*.py")):
            text = py.read_text(encoding="utf-8")
            for m in CITE.finditer(text):
                n_citations += 1
                rel, sec = m.group("file"), m.group("sec")
                if rel not in md_cache:
                    p = ROOT / rel
                    md_cache[rel] = p.read_text(encoding="utf-8") if p.exists() else None
                body = md_cache[rel]
                where = f"{py.relative_to(ROOT)}: cites {m.group(0)!r}"
                if body is None:
                    failures.append(f"{where} — {rel} does not exist")
                elif sec is not None and not section_present(body, sec):
                    failures.append(f"{where} — no section §{sec} in {rel}")
    if failures:
        print("check_docs: FAILED citations:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"check_docs: OK ({n_citations} citations resolved)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
