#!/usr/bin/env bash
# Tier-1 and marked suites, with PYTHONPATH set the way CI expects.
#
#   scripts/test.sh            # tier-1: everything not marked slow/multidevice
#   scripts/test.sh slow       # the slow suite only
#   scripts/test.sh multidevice  # multi-device suite under 8 virtual devices
#   scripts/test.sh all        # tier-1, then slow, then multidevice
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

tier1() {
  # docs gate: every `docs/... §X` / `DESIGN.md §X` cited in a docstring
  # must exist, and the suite must at least collect cleanly
  python scripts/check_docs.py
  # examples gate: every examples/*.py imports cleanly and answers --help
  python scripts/examples_smoke.py
  python -m pytest --collect-only -q >/dev/null
  python -m pytest -x -q -m "not slow and not multidevice" "$@"
}
slow() { python -m pytest -q -m slow "$@"; }
multidevice() {
  XLA_FLAGS="--xla_force_host_platform_device_count=8 ${XLA_FLAGS:-}" \
    python -m pytest -q -m multidevice "$@"
}

case "${1:-tier1}" in
  tier1) tier1 "${@:2}" ;;
  slow) slow "${@:2}" ;;
  multidevice) multidevice "${@:2}" ;;
  all) tier1 "${@:2}"; slow "${@:2}"; multidevice "${@:2}" ;;
  *) echo "usage: $0 [tier1|slow|multidevice|all]" >&2; exit 2 ;;
esac
