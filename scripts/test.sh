#!/usr/bin/env bash
# Tier-1 and marked suites, with PYTHONPATH set the way CI expects.
#
#   scripts/test.sh            # tier-1: everything not marked slow/multidevice/chaos
#   scripts/test.sh slow       # the slow suite only
#   scripts/test.sh multidevice  # multi-device suite under 8 virtual devices
#   scripts/test.sh chaos      # network-fabric loss/partition sweeps
#   scripts/test.sh topo       # fast dissemination-topology suite only
#   scripts/test.sh keyed      # keyed-sharding + segment-reduce suite (8 vdev)
#   scripts/test.sh obs        # telemetry smoke: export + audit a chaos run
#   scripts/test.sh bench      # quick chaos bench + perf-regression gate
#   scripts/test.sh all        # tier-1, then slow, multidevice, chaos, obs
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# stale bytecode from moved/renamed modules shadows fresh sources when
# mtimes go backwards (container snapshots) — purge before collecting
find src -type d -name '__pycache__' -prune -exec rm -rf {} + 2>/dev/null || true

tier1() {
  # docs gate: every `docs/... §X` / `DESIGN.md §X` cited in a docstring
  # must exist, and the suite must at least collect cleanly
  python scripts/check_docs.py
  # examples gate: every examples/*.py imports cleanly and answers --help
  python scripts/examples_smoke.py
  python -m pytest --collect-only -q >/dev/null
  # the fast chaos subset (unmarked tests in tests/test_net.py) runs here;
  # the slow loss/partition sweeps are opt-in via the chaos marker
  python -m pytest -x -q -m "not slow and not multidevice and not chaos" "$@"
}
slow() { python -m pytest -q -m slow "$@"; }
chaos() { python -m pytest -q -m chaos "$@"; }
# topology schedule laws + sparse-vs-oracle convergence (tests/test_topology.py);
# already part of tier-1 — this target is the quick loop while iterating on the
# gossip plane.  The 64/256-node sweeps there are chaos-marked and run with
# `scripts/test.sh chaos`.
topo() { python -m pytest -q -m "not chaos" tests/test_topology.py "$@"; }
obs() {
  # end-to-end telemetry gate: export traces from a small lossy chaos run,
  # audit the protocol invariants, validate the Chrome trace-event schema
  python scripts/obs_smoke.py
}
# keyed/sharded dataplane quick loop: segment-reduce parity + sharding laws
# + the multidevice chaos subprocess tests (which spawn their own 8-vdev
# children, so the flag here only covers anything running in-process)
keyed() {
  XLA_FLAGS="--xla_force_host_platform_device_count=8 ${XLA_FLAGS:-}" \
    python -m pytest -q tests/test_segment_reduce.py tests/test_keyed_sharding.py "$@"
}
multidevice() {
  XLA_FLAGS="--xla_force_host_platform_device_count=8 ${XLA_FLAGS:-}" \
    python -m pytest -q -m multidevice "$@"
}
# perf-regression gate: run the cheap chaos section quick, compare the
# sim-deterministic metrics (latency percentiles, wire bytes) against the
# committed BENCH_pr*.json trajectory (scripts/check_bench.py bands)
bench() {
  local tmp
  tmp="$(mktemp -d)"
  trap 'rm -rf "$tmp"' RETURN
  python -m benchmarks.run --quick --only chaos --json "$tmp/bench.json"
  python scripts/check_bench.py --fresh "$tmp/bench.json" --sections chaos
}

case "${1:-tier1}" in
  tier1) tier1 "${@:2}" ;;
  slow) slow "${@:2}" ;;
  chaos) chaos "${@:2}" ;;
  topo) topo "${@:2}" ;;
  keyed) keyed "${@:2}" ;;
  obs) obs ;;
  bench) bench ;;
  multidevice) multidevice "${@:2}" ;;
  all) tier1 "${@:2}"; slow "${@:2}"; multidevice "${@:2}"; chaos "${@:2}"; obs ;;
  *) echo "usage: $0 [tier1|slow|chaos|topo|keyed|multidevice|all|obs|bench]" >&2; exit 2 ;;
esac
