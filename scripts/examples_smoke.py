#!/usr/bin/env python
"""Examples smoke gate (tier-1, run by scripts/test.sh).

Every file in examples/ must (1) import cleanly as a module — no work at
module scope, so stale imports fail fast without running a demo — and
(2) answer ``--help`` with a zero exit.  This is what keeps the examples
from silently rotting when the API underneath them moves (the drift this
gate was added for: ``launch/stream.py`` grew queries the examples and
benchmarks import).

Exit 0 when every example passes; exit 1 with a listing otherwise.
"""
from __future__ import annotations

import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
EXAMPLES = sorted((ROOT / "examples").glob("*.py"))

IMPORT_SNIPPET = """
import importlib.util, sys
spec = importlib.util.spec_from_file_location({name!r}, {path!r})
mod = importlib.util.module_from_spec(spec)
spec.loader.exec_module(mod)
assert callable(getattr(mod, "main", None)), \
    {name!r} + ": examples must expose a main() entry point"
"""


def main() -> int:
    env = {"PYTHONPATH": str(ROOT / "src")}
    import os

    env = {**os.environ, **env}
    failures: list[str] = []
    for py in EXAMPLES:
        checks = (
            ("import", [sys.executable, "-c",
                        IMPORT_SNIPPET.format(name=py.stem, path=str(py))]),
            ("--help", [sys.executable, str(py), "--help"]),
        )
        for label, cmd in checks:
            try:
                r = subprocess.run(
                    cmd, capture_output=True, text=True, timeout=120, env=env,
                )
            except subprocess.TimeoutExpired:
                failures.append(
                    f"{py.name} [{label}]: timed out after 120s "
                    "(module-scope work in an example?)"
                )
                continue
            if r.returncode != 0:
                tail = (r.stderr or r.stdout).strip().splitlines()[-3:]
                failures.append(f"{py.name} [{label}]: " + " | ".join(tail))
    if failures:
        print("examples_smoke: FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"examples_smoke: OK ({len(EXAMPLES)} examples import + --help)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
