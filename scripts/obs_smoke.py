"""Observability smoke gate (scripts/test.sh obs, docs/observability.md).

End-to-end drive of the telemetry stack on a small chaos run:

1. run both runtimes (Holon + Flink baseline) with ``obs=True`` under a
   lossy fabric with a crash — the scenario that exercises the widest span
   taxonomy (exec/emit/sync/ckpt/steal + net records);
2. export the traces (JSONL + Chrome trace-event JSON) to a temp dir;
3. audit the Holon trace — every protocol invariant must hold;
4. validate the Chrome export against the trace-event schema Perfetto and
   chrome://tracing actually require (ph/ts/pid/tid types, ``X`` events
   carry ``dur``, metadata events name processes).

Exits non-zero on any failure, printing what broke.
"""
from __future__ import annotations

import json
import sys
import tempfile
from pathlib import Path

from repro.obs.audit import audit_harness
from repro.runtime import FailureScenario, SimConfig
from repro.runtime.flink_baseline import FlinkHarness
from repro.runtime.harness import HolonHarness
from repro.streaming import make_q7


def validate_chrome(doc: dict) -> list[str]:
    """Schema check of a Chrome trace-event JSON object (docs/
    observability.md §3): the subset of the spec Perfetto's importer needs."""
    errs = []
    if not isinstance(doc.get("traceEvents"), list):
        return ["traceEvents missing or not a list"]
    for i, ev in enumerate(doc["traceEvents"]):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "M"):
            errs.append(f"{where}: unexpected ph={ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            errs.append(f"{where}: missing name")
        if ph == "M":
            if not isinstance(ev.get("args"), dict):
                errs.append(f"{where}: metadata event without args")
            continue
        for k in ("ts", "pid", "tid"):
            if not isinstance(ev.get(k), (int, float)):
                errs.append(f"{where}: {k} missing or non-numeric")
        if ph == "X" and not isinstance(ev.get("dur"), (int, float)):
            errs.append(f"{where}: complete event without dur")
        if isinstance(ev.get("ts"), (int, float)) and ev["ts"] < 0:
            errs.append(f"{where}: negative ts")
    return errs[:20]


def main() -> int:
    cfg = SimConfig(
        num_nodes=3, num_partitions=4, num_batches=60, window_len=500,
        sync_interval_ms=50.0, ckpt_interval_ms=300.0,
        net_loss=0.02, obs=True,
    )
    q = make_q7(cfg.num_partitions, window_len=cfg.window_len,
                num_slots=cfg.num_slots)
    scen = FailureScenario.concurrent(t=2000.0)
    horizon = cfg.horizon_ms + 10_000.0

    failures = []
    with tempfile.TemporaryDirectory(prefix="holon-obs-smoke-") as td:
        out = Path(td)
        for system, harness_cls in (("holon", HolonHarness),
                                    ("flink", FlinkHarness)):
            h = harness_cls(cfg, q)
            h.run(scen, horizon_ms=horizon)
            jsonl = h.obs.export_jsonl()
            (out / f"{system}.jsonl").write_text(jsonl)
            lines = jsonl.splitlines()
            meta = json.loads(lines[0])
            if meta.get("meta") != "holon-trace-v1":
                failures.append(f"{system}: bad JSONL meta header {lines[0]!r}")
            if len(lines) != h.obs.buf.total - h.obs.buf.dropped + 1:
                failures.append(f"{system}: JSONL line count mismatch")

            chrome = h.obs.export_chrome()
            (out / f"{system}.trace.json").write_text(json.dumps(chrome))
            # re-parse from disk: the validated doc is the exported bytes
            doc = json.loads((out / f"{system}.trace.json").read_text())
            errs = validate_chrome(doc)
            if errs:
                failures.append(f"{system}: chrome schema: {errs}")
            print(f"{system}: {h.obs.buf.total} records, "
                  f"{len(doc['traceEvents'])} chrome events -> {out}")

            rep = audit_harness(h)
            print(f"{system}: {rep}")
            if not rep.ok:
                failures.append(f"{system}: audit failed: {rep.violations}")

        # determinism spot-check: a second same-seed holon run must export
        # byte-identical JSONL
        h2 = HolonHarness(cfg, q)
        h2.run(scen, horizon_ms=horizon)
        if h2.obs.export_jsonl() != (out / "holon.jsonl").read_text():
            failures.append("holon: same-seed trace export not byte-identical")

    if failures:
        print("OBS SMOKE FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("obs smoke OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
