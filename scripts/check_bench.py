#!/usr/bin/env python
"""Perf-regression gate: compare a fresh benchmark JSON against the
committed trajectory (``BENCH_pr*.json``).

The benchmark rows carry their numbers in the ``derived`` string as
``k=v;k=v`` fields.  This gate parses both files, matches rows by name, and
checks the regression-sensitive metrics against per-metric tolerance bands:

* **latency** (``avg_ms``/``p50_ms``/``p99_ms``/``path_p*_ms``/
  ``ttr_max_ms``/``settle_ms``) — sim-time numbers, deterministic for a
  given config, so the same-provenance band is tight;
* **bytes** (``wire_mb``, ``sync*bytes*``, ``shipped`` …) — sync-plane
  traffic, also deterministic per config;
* **overhead** (``overhead_pct``) — wall-clock, noisy: rows whose committed
  value is inside the absolute ceiling (the documented <5% budget plus
  measurement slack) must stay under both the ceiling and 1.5x their
  committed value; rows committed above the ceiling (the baseline's
  tracing overhead on a near-free sim is inherently large) are gated on
  their trajectory instead.

Quick runs and full runs use different workload sizes, so when the two
files' section provenance differs (``section_meta.quick``) the ratio bands
widen to an order-of-magnitude sanity check instead of a tight gate.
Rows or sections present on only one side are skipped (the gate is for
regressions, not coverage).

Usage:
  python scripts/check_bench.py --fresh /tmp/bench.json \
      [--committed BENCH_pr10.json] [--sections chaos,obs]

Exit 0 when every checked metric is in band; exit 1 with a per-metric
report otherwise.  ``scripts/test.sh bench`` runs the cheap chaos section
quick and gates it through here.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

# metric-name pattern -> band class; first match wins
PATTERNS = (
    (re.compile(r"^(avg|p50|p99)_ms$"), "latency"),
    (re.compile(r"^(path|hops)_p\d+(_ms)?$"), "latency"),
    (re.compile(r"^(ttr_max|settle)_ms$"), "latency"),
    (re.compile(r"^overhead_pct$"), "overhead"),
    (re.compile(r"(bytes|_mb)", re.IGNORECASE), "bytes"),
)
# (relative tolerance same-provenance, relative tolerance cross-provenance,
#  absolute slack added on top)
BANDS = {
    "latency": (0.30, 4.0, 5.0),
    "bytes": (0.30, 4.0, 1.0),
}
# the documented telemetry/monitor budget is <5%; wall-clock measurement of
# a few-ms delta is noisy, so the gate allows the budget plus slack
OVERHEAD_CEILING_PCT = 8.0


def parse_derived(derived: str) -> dict[str, float]:
    """The numeric ``k=v`` fields of a row's derived string (non-numeric
    fields like ``audit=ok`` or ``ttr_ms=0:123,...`` are skipped)."""
    out: dict[str, float] = {}
    for part in derived.split(";"):
        k, sep, v = part.partition("=")
        if not sep:
            continue
        try:
            out[k.strip()] = float(v)
        except ValueError:
            continue
    return out


def load(path: Path) -> tuple[dict, dict]:
    doc = json.loads(path.read_text())
    rows = {
        section: {r["name"]: r for r in rws if isinstance(r, dict) and "name" in r}
        for section, rws in (doc.get("sections") or {}).items()
    }
    return rows, doc.get("section_meta") or {}


def band_of(metric: str) -> str | None:
    for pat, band in PATTERNS:
        if pat.search(metric):
            return band
    return None


def check(fresh_path: Path, committed_path: Path,
          sections: list[str] | None = None) -> list[str]:
    """Returns the list of violations (empty = gate passes)."""
    fresh, fresh_meta = load(fresh_path)
    committed, committed_meta = load(committed_path)
    problems: list[str] = []
    checked = 0
    for section, rows in fresh.items():
        if sections and section not in sections:
            continue
        base_rows = committed.get(section)
        if not base_rows:
            continue
        same_provenance = (
            bool(fresh_meta.get(section, {}).get("quick"))
            == bool(committed_meta.get(section, {}).get("quick"))
        )
        for name, row in rows.items():
            base = base_rows.get(name)
            if base is None:
                continue
            got = parse_derived(row.get("derived", ""))
            want = parse_derived(base.get("derived", ""))
            for metric, new in got.items():
                if metric not in want:
                    continue
                band = band_of(metric)
                if band is None:
                    continue
                old = want[metric]
                checked += 1
                if band == "overhead":
                    if old <= OVERHEAD_CEILING_PCT:
                        # a row inside the ceiling must stay there — but a
                        # commit near the ceiling gets 1.5x headroom so
                        # measurement noise alone can't trip the gate
                        if new > OVERHEAD_CEILING_PCT and new > old * 1.5:
                            problems.append(
                                f"{name}: {metric}={new:.1f} exceeds the "
                                f"{OVERHEAD_CEILING_PCT:.0f}% ceiling "
                                f"(committed {old:.1f})"
                            )
                    elif new > old * 3.0 + 5.0:
                        # committed value already above the ceiling (e.g.
                        # tracing overhead on the baseline's near-free sim):
                        # gate the trajectory, not the absolute budget
                        problems.append(
                            f"{name}: {metric}={new:.1f} regressed past 3x "
                            f"the committed {old:.1f}"
                        )
                    continue
                rel_same, rel_cross, abs_slack = BANDS[band]
                tol = rel_same if same_provenance else rel_cross
                limit = old * (1.0 + tol) + abs_slack
                if new > limit:
                    prov = "same" if same_provenance else "quick/full mismatch"
                    problems.append(
                        f"{name}: {metric}={new:.2f} regressed past "
                        f"{limit:.2f} (committed {old:.2f}, band +{tol:.0%} "
                        f"[{prov} provenance] + {abs_slack:g} abs)"
                    )
    print(f"check_bench: {checked} metrics checked against "
          f"{committed_path.name}, {len(problems)} regression(s)")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", type=Path, required=True,
                    help="benchmark JSON produced by this run")
    ap.add_argument("--committed", type=Path, default=None,
                    help="baseline JSON (default: newest BENCH_pr*.json)")
    ap.add_argument("--sections", type=str, default=None,
                    help="comma-separated sections to gate (default: all "
                         "sections present in both files)")
    args = ap.parse_args(argv)
    committed = args.committed
    if committed is None:
        cands = sorted(
            REPO.glob("BENCH_pr*.json"),
            key=lambda p: int(re.search(r"pr(\d+)", p.name).group(1)),
        )
        if not cands:
            print("check_bench: no committed BENCH_pr*.json to compare against")
            return 0
        committed = cands[-1]
    sections = args.sections.split(",") if args.sections else None
    problems = check(args.fresh, committed, sections)
    for p in problems:
        print(f"  REGRESSION {p}")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
