"""Million-key keyed-state scaling sweep (docs/protocol.md §6).

Zipf-skewed per-auction bid counting over key domains C ∈ {1e4, 1e6, 1e7}
on 8- and 48-way ``--xla_force_host_platform_device_count`` meshes, comparing

* **sharded** — the hash-partitioned keyed dataplane
  (``launch.stream.build_keyed_pipeline``): each device owns a
  ``[W, ceil(C/S)]`` key range, events ride one all-to-all per fold step,
  the sync plane ships only the ``[S]`` progress map;
* **dense**  — the replicate-everywhere ``build_pipeline`` + ``make_q5``
  path, where every device folds the full ``[W, S, C]`` keyed lattice and
  delta sync gathers replica stacks of it.

Rows report events/s, per-device state bytes, and shuffle/sync bytes per
round.  Dense runs above a host-memory budget are NOT attempted: the sync
gather alone would stack ``S`` full replicas per device (e.g. ~2 GB/device
at C=1e6 on 8 devices), so those rows carry ``skipped=1`` plus the byte
estimates that ruled them out — the sharded rows at the same (C, S) complete,
which is the point of the sweep.

Each (C, S, mode) cell runs in a fresh subprocess because the virtual device
count is fixed at jax import time (same pattern as the multidevice tests).

Usage: PYTHONPATH=src python -m benchmarks.keyed_scale  (or via benchmarks.run)
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from benchmarks.common import emit, memory_fields

KEY_DOMAINS = (10_000, 1_000_000, 10_000_000)
MESH_SIZES = (8, 48)
KEY_SKEW = 1.1
WINDOW_LEN = 100
NUM_SLOTS = 8
SYNC_EVERY = 4
# dense-path budget: the delta-sync gather stacks S replicas of the [W, S, C]
# state on every device — refuse to attempt a dense cell whose modeled stack
# exceeds this (the host has ~1 core; thrashing tells us nothing new)
DENSE_BUDGET_BYTES = 1.5e9


def dense_state_bytes(n_dev: int, keys: int) -> float:
    """Per-device dense q5 keyed-lattice bytes: [W, S, C] f32."""
    return float(NUM_SLOTS * n_dev * keys * 4)


# one EventBatch lane on device: ts i32 + kind i32 + auction u32 + price f32
# + category i32 + bidder u32 + valid bool
EVENT_BYTES = 25


def modeled_peak_bytes(mode: str, n_dev: int, keys: int, batches: int,
                       epb: int, state_bytes: float) -> float:
    """Modeled per-device peak live bytes: resident window state + the
    device's input-log slice + the mode's dominant transient — sharded: the
    double-buffered ``[S, B]`` all-to-all routing matrices (ts/local i32 +
    mask bool, in + out); dense: the S-replica stack the sync gather
    materializes.  A model, like every byte counter here: CPU XLA reports
    no usable per-device temp stats to measure against (its compiled
    ``temp_size_in_bytes`` is 0), and the model is exactly what rules dense
    cells in or out of the sweep."""
    log_bytes = batches * epb * EVENT_BYTES
    if mode == "sharded":
        work = 2 * (4 + 4 + 1) * n_dev * epb
    else:
        work = state_bytes * n_dev
    return state_bytes + log_bytes + work


def _worker(args) -> None:
    """Runs in the subprocess (XLA_FLAGS set by the parent): one measured
    cell, result as a ``KEYED_RESULT {...}`` JSON line on stdout."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import compat
    from repro.core import wcrdt as W
    from repro.core.window import as_assigner
    from repro.launch.mesh import make_data_mesh
    from repro.launch.stream import (
        MAKERS, build_keyed_pipeline, build_pipeline, default_fold_schedule,
        read_window_range,
    )
    from repro.streaming.generator import NexmarkConfig, generate_log

    S, C, nb, epb = args.n_dev, args.keys, args.batches, args.epb
    assert len(jax.devices()) == S, (len(jax.devices()), S)
    nx = NexmarkConfig(num_partitions=S, num_batches=nb, events_per_batch=epb,
                       num_auctions=C, key_skew=args.key_skew)
    log = generate_log(nx)
    horizon = nb * nx.batch_span_ms
    rounds = max(nb // SYNC_EVERY, 1)

    if args.mode == "sharded":
        shards = W.KeyShards(C, S)
        mesh = make_data_mesh(S)
        assigner = as_assigner(WINDOW_LEN, WINDOW_LEN // 2)
        spec = W.wgcounter_sharded(WINDOW_LEN, NUM_SLOTS, S, shards,
                                   assigner=assigner)
        closed = int(assigner.first_dirty_wid(horizon))
        n_win = max(1, min(closed, 2))
        first = max(0, closed - n_win)
        table = jnp.asarray(shards.key_table())
        sched = jnp.asarray(default_fold_schedule(S, nb))
        wm = jnp.ones((rounds,), bool)
        with mesh:
            pipe = build_keyed_pipeline(
                mesh, shards, window_len=WINDOW_LEN, num_slots=NUM_SLOTS,
                sync_every=SYNC_EVERY, n_windows=n_win, first_window=first,
            )
            oks, vals, shuf, sync = pipe(log, table, sched, wm)
            jax.block_until_ready(oks)
            t0 = time.time()
            oks, vals, shuf, sync = pipe(log, table, sched, wm)
            jax.block_until_ready(oks)
            dt = time.time() - t0
        out = {
            "events_per_s": S * nb * epb / dt,
            "state_bytes_per_dev": float(W.state_nbytes(spec.zero())),
            "shuffle_bytes_per_round": float(np.asarray(shuf).mean()) / rounds,
            "sync_bytes_per_round": float(np.asarray(sync).mean()) / rounds,
            "ok_windows": int(np.asarray(oks)[0].sum()),
            "width": shards.width,
        }
    else:  # dense
        mesh = compat.make_mesh((S,), ("data",))
        query = MAKERS["q5"](S, window_len=WINDOW_LEN, num_slots=NUM_SLOTS,
                             num_auctions=C)
        first, n_win = read_window_range(query, horizon)
        with mesh:
            pipe = build_pipeline(query, mesh, SYNC_EVERY,
                                  n_windows=n_win, first_window=first)
            oks, vals, sb = pipe(log)
            jax.block_until_ready(oks)
            t0 = time.time()
            oks, vals, sb = pipe(log)
            jax.block_until_ready(oks)
            dt = time.time() - t0
        out = {
            "events_per_s": S * nb * epb / dt,
            "state_bytes_per_dev": float(
                sum(W.state_nbytes(st) for st in query.init_shared())
            ),
            "shuffle_bytes_per_round": 0.0,  # dense path never shuffles events
            "sync_bytes_per_round": float(np.asarray(sb).mean()) / rounds,
            "ok_windows": int(np.asarray(oks)[0].sum()),
            "width": C,
        }
    print("KEYED_RESULT " + json.dumps(out))


def _run_cell(n_dev: int, keys: int, mode: str, batches: int, epb: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    cmd = [
        sys.executable, "-m", "benchmarks.keyed_scale", "--worker",
        "--n-dev", str(n_dev), "--keys", str(keys), "--mode", mode,
        "--batches", str(batches), "--epb", str(epb),
        "--key-skew", str(KEY_SKEW),
    ]
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=3600, env=env)
    for line in r.stdout.splitlines():
        if line.startswith("KEYED_RESULT "):
            return json.loads(line[len("KEYED_RESULT "):])
    raise RuntimeError(
        f"worker {mode} C={keys} S={n_dev} failed:\n"
        f"stdout={r.stdout[-1500:]}\nstderr={r.stderr[-1500:]}"
    )


def _label(keys: int, n_dev: int) -> str:
    return f"C{keys:.0e}_dev{n_dev}".replace("e+0", "e")


def main(quick: bool = False) -> None:
    from benchmarks.common import timer

    batches, epb = (8, 128) if quick else (8, 256)
    state_by_c: dict[int, dict[int, float]] = {}
    for keys in KEY_DOMAINS:
        for n_dev in MESH_SIZES:
            lbl = _label(keys, n_dev)
            with timer() as tm:
                res = _run_cell(n_dev, keys, "sharded", batches, epb)
            state_by_c.setdefault(keys, {})[n_dev] = res["state_bytes_per_dev"]
            emit(
                f"keyed/sharded/{lbl}",
                tm.dt * 1e6,
                f"events_per_s={res['events_per_s']:.0f};"
                + memory_fields(
                    res["state_bytes_per_dev"],
                    modeled_peak_bytes("sharded", n_dev, keys, batches, epb,
                                       res["state_bytes_per_dev"]),
                )
                + f";shuffle_bytes_per_round={res['shuffle_bytes_per_round']:.0f}"
                f";sync_bytes_per_round={res['sync_bytes_per_round']:.0f}"
                f";width={res['width']};ok_windows={res['ok_windows']}",
            )

            # dense comparand, only inside the memory budget: the sync
            # gather stacks S replicas of the per-device state
            est_state = dense_state_bytes(n_dev, keys)
            est_stack = est_state * n_dev
            if est_stack > DENSE_BUDGET_BYTES:
                emit(
                    f"keyed/dense/{lbl}", 0.0,
                    "skipped=1;"
                    + memory_fields(
                        est_state,
                        modeled_peak_bytes("dense", n_dev, keys, batches,
                                           epb, est_state),
                    )
                    + f";est_sync_stack_bytes={est_stack:.0f}",
                )
                continue
            with timer() as tm:
                res = _run_cell(n_dev, keys, "dense", batches, epb)
            emit(
                f"keyed/dense/{lbl}",
                tm.dt * 1e6,
                f"events_per_s={res['events_per_s']:.0f};"
                + memory_fields(
                    res["state_bytes_per_dev"],
                    modeled_peak_bytes("dense", n_dev, keys, batches, epb,
                                       res["state_bytes_per_dev"]),
                )
                + f";sync_bytes_per_round={res['sync_bytes_per_round']:.0f}"
                f";ok_windows={res['ok_windows']}",
            )

    # the headline scaling law: per-device state shrinks ~1/n_dev
    for keys, by_dev in state_by_c.items():
        if len(by_dev) == 2:
            lo, hi = min(by_dev), max(by_dev)
            emit(
                f"keyed/state_scaling/C{keys:.0e}".replace("e+0", "e"),
                0.0,
                f"dev{lo}_over_dev{hi}={by_dev[lo]/by_dev[hi]:.2f};"
                f"ideal={hi/lo:.2f}",
            )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--n-dev", type=int, default=8)
    ap.add_argument("--keys", type=int, default=10_000)
    ap.add_argument("--mode", choices=("sharded", "dense"), default="sharded")
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--epb", type=int, default=256)
    ap.add_argument("--key-skew", type=float, default=KEY_SKEW)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.worker:
        _worker(args)
    else:
        print("name,us_per_call,derived")
        main(quick=args.quick)
