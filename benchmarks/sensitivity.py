"""Paper Fig. 7/8: latency sensitivity (Stabl-style) across failure scenarios.

Sensitivity = area between the with-failures latency curve and the
failure-free baseline, summed over common (partition, window) keys.
"""
from __future__ import annotations

from benchmarks.common import emit, timer
from repro.runtime import FailureScenario, SimConfig, run_flink, run_holon
from repro.streaming import make_q7


def main(quick: bool = False):
    cfg = SimConfig(num_batches=200 if quick else 400)
    q = make_q7(cfg.num_partitions, window_len=cfg.window_len, num_slots=cfg.num_slots)

    out = {}
    for system, runner in (("holon", run_holon), ("flink", run_flink)):
        base = runner(cfg, q, FailureScenario.baseline(), horizon_ms=cfg.horizon_ms + 20_000)
        for name, scen in (
            ("concurrent", FailureScenario.concurrent()),
            ("subsequent", FailureScenario.subsequent()),
        ):
            with timer() as tm:
                c = runner(cfg, q, scen, horizon_ms=cfg.horizon_ms + 20_000)
            sens = c.sensitivity(base)
            out[(system, name)] = sens
            emit(f"fig7_8_sensitivity/{system}/{name}", tm.dt * 1e6, f"sensitivity_s={sens:.2f}")

    for name in ("concurrent", "subsequent"):
        h, f = out.get(("holon", name), 0), out.get(("flink", name), 0)
        if h > 0:
            emit(f"fig7_8_sensitivity/ratio/{name}", 0.0, f"flink_over_holon_x={f/h:.1f}")
    return out


if __name__ == "__main__":
    main()
