"""Paper Fig. 6 + Table 2: latency/throughput under node-failure scenarios.

Runs Q7 on the decentralized Holon runtime and the centralized Flink-like
baseline across the paper's scenarios (baseline / concurrent / subsequent /
crash, plus Flink-with-spare-slots), reporting avg & p99 end-to-end window
latency in simulated ms, plus Holon's recovery time (latency-spike width).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import emit, latency_fields, timer
from repro.runtime import FailureScenario, SimConfig, run_flink, run_holon
from repro.streaming import make_q7


def scenarios(membership: tuple[int, ...]):
    """The paper's §5.2 scenarios over the first two members of the actual
    membership set — node ids come from the config, not hardcoded, so the
    sweep keeps working when the initial membership is reconfigured."""
    pair = tuple(membership[:2])
    return {
        "baseline": FailureScenario.baseline(),
        "concurrent": FailureScenario.concurrent(nodes=pair),
        "subsequent": FailureScenario.subsequent(nodes=pair),
        "crash": FailureScenario.crash(nodes=pair),
    }


def recovery_time_ms(consumer, baseline_avg: float, window_len: float) -> float:
    """Width of the latency spike: time from first window whose latency
    exceeds 3x the failure-free average until latencies return below it."""
    t, lat = consumer.latency_series()
    bad = lat > 3.0 * max(baseline_avg, 1.0)
    if not bad.any():
        return 0.0
    return float(t[bad].max() - t[bad].min() + window_len)


def main(quick: bool = False):
    cfg = SimConfig(num_batches=200 if quick else 400)
    q = make_q7(cfg.num_partitions, window_len=cfg.window_len, num_slots=cfg.num_slots)
    results = {}
    base_avg = {}

    for system, runner, cfgv in (
        ("holon", run_holon, cfg),
        ("flink", run_flink, cfg),
        ("flink_spare", run_flink, dataclasses.replace(cfg, flink_spare_slots=True)),
    ):
        for name, scen in scenarios(cfgv.initial_membership).items():
            if system == "flink_spare" and name == "baseline":
                continue
            with timer() as tm:
                c = runner(cfgv, q, scen, horizon_ms=cfgv.horizon_ms + 20_000)
            s = c.latency_stats()
            results[(system, name)] = s
            if name == "baseline":
                base_avg[system] = s["avg"]
            rec = recovery_time_ms(c, base_avg.get(system, s["avg"]), cfg.window_len)
            # delta-sync bandwidth (holon only): bytes shipped vs what
            # full-state broadcast would have cost over the same run
            sync_mb = getattr(c, "sync_bytes", 0.0) / 1e6
            sync_full_mb = getattr(c, "sync_bytes_full", 0.0) / 1e6
            nacks = getattr(c, "sync_nacks", 0)
            emit(
                f"fig6_table2/{system}/{name}",
                tm.dt * 1e6,
                f"{latency_fields(s)};recovery_ms={rec:.0f};"
                f"sync_mb={sync_mb:.2f};full_sync_mb={sync_full_mb:.2f};sync_nacks={nacks}",
            )

    # headline paper ratios
    try:
        r_base = results[("flink", "baseline")]["avg"] / results[("holon", "baseline")]["avg"]
        r_fail = results[("flink", "concurrent")]["avg"] / results[("holon", "concurrent")]["avg"]
        emit("fig6_table2/ratio", 0.0, f"baseline_latency_x={r_base:.1f};concurrent_latency_x={r_fail:.1f}")
    except (KeyError, ZeroDivisionError):
        pass
    return results


if __name__ == "__main__":
    main()
