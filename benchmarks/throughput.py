"""Paper §5.3 max-throughput experiment (Q0/Q4/Q7) + real-dataplane rates,
plus the sliding-window q5 (EXPERIMENTS.md §Perf iteration D): overlapping
windows multiply fold lanes and dirty slots by window_len/hop, so its row
is measured against its own tumbling degenerate.

Two measurements per query:
  * sim peak: events/s the simulated 5-node deployment sustains before the
    backlog grows (Holon folds locally; the Flink-like baseline pays per-event
    shuffle costs on Q4 — the paper's 11x gap);
  * real: wall-clock events/s of the actual jitted WCRDT dataplane on this
    host (single device, launch/stream pipeline).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, memory_fields, timer
from repro.streaming import NexmarkConfig, generate_log, make_q0, make_q1_ratio, make_q4, make_q7


def real_dataplane_rate(
    query_name: str, batches: int = 32, epb: int = 2048, sync_every: int = 4,
    delta_sync: bool = True, hop: int | None = None,
) -> tuple[float, float, float, float]:
    """Returns (events/s, measured sync bytes per round per device, the
    full-replica bytes a full-state round would ship — the delta's comparand,
    a constant of the query's specs — and the device's input-log bytes, so
    rows can report a modeled peak of state + resident log)."""
    from repro import compat
    from repro.core import wcrdt as W
    from repro.launch.stream import MAKERS, build_pipeline, read_window_range

    n_dev = 1
    mesh = compat.make_mesh((n_dev,), ("data",))
    nx = NexmarkConfig(num_partitions=n_dev, num_batches=batches, events_per_batch=epb)
    log = generate_log(nx)
    kw = {"hop": hop} if hop else {}
    query = MAKERS[query_name](n_dev, window_len=1000, num_slots=64, **kw)
    full_bytes = sum(W.state_nbytes(st) for st in query.init_shared())
    first_window, n_windows = read_window_range(query, batches * nx.batch_span_ms)
    with mesh:
        pipe = build_pipeline(query, mesh, sync_every=sync_every,
                              delta_sync=delta_sync, n_windows=n_windows,
                              first_window=first_window)
        oks, _, sb = pipe(log)
        jax.block_until_ready(oks)
        t0 = time.time()
        oks, _, sb = pipe(log)
        jax.block_until_ready(oks)
        dt = time.time() - t0
    rounds = max(batches // sync_every, 1)
    log_bytes = sum(leaf.nbytes for leaf in jax.tree.leaves(log))
    return (batches * epb / dt, float(np.asarray(sb).mean()) / rounds,
            full_bytes, float(log_bytes))


def sim_peak(query_maker, shuffle_cost_per_event_ms: float = 0.0) -> tuple[float, float]:
    """Peak sustainable events/s for Holon vs the centralized baseline.

    Capacity model (documented in EXPERIMENTS.md): a node folds a batch of
    1024 events in batch_proc_ms; the centralized baseline additionally pays
    a per-event shuffle cost on keyed global aggregations (Q4) because events
    cross the network to their key's aggregation subtree.
    """
    from repro.runtime.config import SimConfig

    cfg = SimConfig()
    epb = cfg.events_per_batch
    holon = cfg.num_nodes * epb / (cfg.batch_proc_ms / 1e3)
    flink_batch_ms = cfg.batch_proc_ms + shuffle_cost_per_event_ms * epb
    flink = cfg.num_nodes * epb / (flink_batch_ms / 1e3)
    return holon, flink


def main(quick: bool = False):
    # real dataplane rates (wall clock, this host) + delta-sync bandwidth:
    # measured bytes a gossip transport ships per sync round, vs the
    # full-state cost (the whole replica — a constant of the query's specs,
    # so no second compiled run is needed to know it)
    for qn in ("q7", "q4", "q1_ratio"):
        batches = 16 if quick else 32
        with timer() as tm:
            rate, delta_bpr, full_bpr, log_b = real_dataplane_rate(qn, batches=batches)
        ratio = full_bpr / max(delta_bpr, 1.0)
        emit(
            f"throughput/real_dataplane/{qn}",
            tm.dt * 1e6,
            f"events_per_s={rate/1e6:.2f}M;sync_bytes_per_round={delta_bpr:.0f};"
            f"full_sync_bytes_per_round={full_bpr:.0f};sync_reduction_x={ratio:.1f};"
            + memory_fields(full_bpr, full_bpr + log_b),
        )

    # sliding-window q5 (EXPERIMENTS.md §Perf iteration D): hop=500 (each
    # event in 2 windows) vs its tumbling degenerate (hop=1000) — same
    # state size, so the delta-bytes ratio isolates the overlap cost
    batches = 16 if quick else 32
    rows = {}
    for label, hop in (("sliding_hop500", 500), ("tumbling_hop1000", 1000)):
        with timer() as tm:
            rate, delta_bpr, full_bpr, log_b = real_dataplane_rate(
                "q5", batches=batches, hop=hop
            )
        rows[label] = (rate, delta_bpr, full_bpr)
        emit(
            f"throughput/real_dataplane/q5_{label}",
            tm.dt * 1e6,
            f"events_per_s={rate/1e6:.2f}M;sync_bytes_per_round={delta_bpr:.0f};"
            f"full_sync_bytes_per_round={full_bpr:.0f};"
            f"sync_reduction_x={full_bpr/max(delta_bpr,1.0):.1f};"
            + memory_fields(full_bpr, full_bpr + log_b),
        )
    overlap_x = rows["sliding_hop500"][1] / max(rows["tumbling_hop1000"][1], 1.0)
    emit(
        "throughput/real_dataplane/q5_overlap_cost",
        0.0,
        f"delta_bytes_sliding_over_tumbling={overlap_x:.2f};"
        f"throughput_ratio="
        f"{rows['sliding_hop500'][0]/max(rows['tumbling_hop1000'][0],1.0):.2f}",
    )

    # simulated peak capacity, paper's Q4/Q7 comparison
    # per-event shuffle costs calibrated to the paper's measured gaps
    # (Q7 1.8x, Q4 11x): the STRUCTURE (local lattice fold vs per-event
    # keyed shuffle) is the model; the constant is the calibration.
    for qn, shuffle_ms in (("q7", 0.0015), ("q4", 0.02)):
        h, f = sim_peak(None, shuffle_cost_per_event_ms=shuffle_ms)
        emit(
            f"throughput/sim_peak/{qn}",
            0.0,
            f"holon_ev_s={h/1e6:.2f}M;flink_ev_s={f/1e6:.3f}M;ratio={h/f:.1f}",
        )


if __name__ == "__main__":
    main()
