"""Observability section: per-phase latency breakdown, trace-derived
recovery timelines, and the telemetry overhead budget (docs/observability.md).

Rows (section ``obs``):

* ``obs/phase/<phase>/<system>`` — where a window's end-to-end latency goes:
  ``queue`` (batch availability → dequeue), ``process`` (modeled fold cost),
  ``emit`` (window close → first emission) from the ``phase_ms`` histograms,
  plus ``sync_wire``/``shuffle_wire`` from the fabric's per-class
  ``net_delivery_ms`` — the transport slice of the sync phase.
* ``obs/recovery/<scenario>/<system>`` — the auditor's trace-extracted
  timelines: per-crash ``time_to_recover_ms`` (crash → last owned-partition
  re-adoption) for Holon, ``flink_downtime_ms`` for the baseline, and
  ``time_to_settle_ms`` for both — measured from what actually happened in
  the trace, not from consumer-side heuristics.
* ``obs/overhead/<system>`` — same run with telemetry off vs on; the
  acceptance budget is <5% wall-clock slowdown, and the row carries the
  measured number so regressions are visible in the perf trajectory.

Every audited run must pass — a violation raises, so the benchmark doubles
as a protocol gate on exactly the configurations the paper reports.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, timer
from repro.obs.audit import audit_harness
from repro.runtime import FailureScenario, SimConfig
from repro.runtime.flink_baseline import FlinkHarness
from repro.runtime.harness import HolonHarness
from repro.streaming import make_q7

PHASES = ("queue", "process", "emit")
WIRE = {"holon": ("sync", "ckpt_put"), "flink": ("shuffle",)}
SYSTEMS = {"holon": HolonHarness, "flink": FlinkHarness}


def _cfg(quick: bool) -> SimConfig:
    return SimConfig(
        num_batches=120 if quick else 240,
        window_len=500,
        num_slots=64,
        sync_interval_ms=50.0,
        ckpt_interval_ms=500.0,
    )


def _hist_fields(h) -> str:
    return f"avg_ms={h.avg:.2f};p50_ms={h.percentile(50):.2f};" \
           f"p99_ms={h.percentile(99):.2f};n={h.count}"


def main(quick: bool = False):
    cfg = _cfg(quick)
    q = make_q7(cfg.num_partitions, window_len=cfg.window_len, num_slots=cfg.num_slots)
    horizon = cfg.horizon_ms + 20_000.0
    t_fail = horizon * 0.3
    scen = FailureScenario.concurrent(t=t_fail)
    cfg_obs = dataclasses.replace(cfg, obs=True)

    harnesses = {}
    repeats = 2 if quick else 5
    for system, harness_cls in SYSTEMS.items():
        # warmup run first so the off/on comparison isn't skewed by JIT
        # compilation of the query dataplane (cached by function identity);
        # time only .run() — construction (log generation) is shared cost.
        # CPU JAX dispatch noise between identical runs (±10%) dwarfs the
        # telemetry delta, so: run off/on back-to-back PAIRS (adjacent runs
        # share thermal/cache state), take each pair's on/off ratio, and
        # report the median ratio — robust to the slow drift and outlier
        # stalls that make ratio-of-mins swing run to run.
        harness_cls(cfg, q).run(scen, horizon_ms=horizon)
        pairs, best_on = [], None
        for _ in range(repeats):
            ts = {}
            for label, c in (("off", cfg), ("on", cfg_obs)):
                h = harness_cls(c, q)
                with timer() as tm:
                    h.run(scen, horizon_ms=horizon)
                ts[label] = tm.dt
                if label == "on" and (best_on is None or tm.dt < best_on[0]):
                    best_on = (tm.dt, h)
            pairs.append((ts["off"], ts["on"]))
        t_off = min(p[0] for p in pairs)
        t_on = best_on[0]
        h = harnesses[system] = best_on[1]
        ratios = sorted(on / max(off, 1e-9) for off, on in pairs)
        overhead = (ratios[len(ratios) // 2] - 1.0) * 100.0
        emit(
            f"obs/overhead/{system}", t_on * 1e6,
            f"off_ms={t_off * 1e3:.0f};on_ms={t_on * 1e3:.0f};"
            f"overhead_pct={overhead:.1f};repeats={repeats};"
            f"trace_records={h.obs.buf.total}",
        )

    # ---- per-phase latency breakdown ---------------------------------------
    for system, h in harnesses.items():
        reg = h.obs.registry
        for phase in PHASES:
            hist = reg.histograms("phase_ms").get(f"phase_ms{{phase={phase}}}")
            if hist is not None and hist.count:
                emit(f"obs/phase/{phase}/{system}", 0.0, _hist_fields(hist))
        for cls in WIRE[system]:
            hist = reg.histograms("net_delivery_ms").get(
                f"net_delivery_ms{{cls={cls}}}"
            )
            if hist is not None and hist.count:
                emit(f"obs/phase/{cls}_wire/{system}", 0.0, _hist_fields(hist))

    # ---- trace-derived recovery timelines (crash + partition) --------------
    members = cfg.initial_membership
    groups = (members[: len(members) // 2], members[len(members) // 2:])
    from repro.runtime import Scenario

    part_scen = (
        Scenario("partition").partition(t_fail, *groups).heal(t_fail + 6000.0)
    )
    for scen_name, scenario in (("concurrent_crash", scen), ("partition", part_scen)):
        for system, harness_cls in SYSTEMS.items():
            h = harnesses[system] if scenario is scen else harness_cls(cfg_obs, q)
            if scenario is not scen:
                h.run(scenario, horizon_ms=horizon)
            rep = audit_harness(h)
            if not rep.ok:
                raise AssertionError(
                    f"auditor failed on obs/{scen_name}/{system}:\n{rep}"
                )
            ttr = rep.metrics.get("time_to_recover_ms", {})
            down = rep.metrics.get("flink_downtime_ms", [])
            fields = [
                "audit=ok",
                f"settle_ms={rep.metrics.get('time_to_settle_ms', 0.0):.0f}",
            ]
            if ttr:
                worst = max(ttr.values())
                fields.append(f"ttr_max_ms={worst:.0f}")
                fields.append(
                    "ttr_ms=" + ",".join(f"{n}:{t:.0f}" for n, t in ttr.items())
                )
            if down:
                fields.append(
                    "downtime_ms=" + ",".join(
                        "inf" if d == float("inf") else f"{d:.0f}" for d in down
                    )
                )
            emit(f"obs/recovery/{scen_name}/{system}", 0.0, ";".join(fields))

    return harnesses


if __name__ == "__main__":
    main()
