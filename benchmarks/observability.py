"""Observability section: per-phase latency breakdown, trace-derived
recovery timelines, and the telemetry overhead budget (docs/observability.md).

Rows (section ``obs``):

* ``obs/phase/<phase>/<system>`` — where a window's end-to-end latency goes:
  ``queue`` (batch availability → dequeue), ``process`` (modeled fold cost),
  ``emit`` (window close → first emission) from the ``phase_ms`` histograms,
  plus ``sync_wire``/``shuffle_wire`` from the fabric's per-class
  ``net_delivery_ms`` — the transport slice of the sync phase.
* ``obs/recovery/<scenario>/<system>`` — the auditor's trace-extracted
  timelines: per-crash ``time_to_recover_ms`` (crash → last owned-partition
  re-adoption) for Holon, ``flink_downtime_ms`` for the baseline, and
  ``time_to_settle_ms`` for both — measured from what actually happened in
  the trace, not from consumer-side heuristics.
* ``obs/overhead/<system>`` — same run with telemetry off vs on; the
  acceptance budget is <5% wall-clock slowdown, and the row carries the
  measured number so regressions are visible in the perf trajectory.
* ``obs/critpath/<topology>/<system>`` — causal critical-path attribution
  (docs/observability.md §5): per emitted window the chain of trace records
  that gated the emission, its hop-count and length distributions, and the
  per-phase split (queue/compute/sync_wait/loss_stall/wire/recovery) — one
  row per dissemination topology (all-to-all, ring, hypercube, partial)
  plus the Flink tree, under a lossy/jittered fabric so the stall phases
  are exercised.  This is the causal explanation behind the latency
  percentiles the other sections report.
* ``obs/monitor/<system>`` — the online monitor (docs/observability.md §6)
  riding the same chaos run: alert counts by id, the invariant-violation
  count (must be 0 — a violation raises), and the monitor's directly
  measured cost — wall time spent inside the subscribed feed against the
  rest of the run (budget <5%).

Every audited run must pass — a violation raises, so the benchmark doubles
as a protocol gate on exactly the configurations the paper reports.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from time import perf_counter

from benchmarks.common import emit, timer
from repro.obs.audit import audit_harness
from repro.obs.critpath import PHASES as CP_PHASES
from repro.obs.critpath import analyze_harness
from repro.runtime import FailureScenario, SimConfig
from repro.runtime.flink_baseline import FlinkHarness
from repro.runtime.harness import HolonHarness
from repro.streaming import make_q7

PHASES = ("queue", "process", "emit")
WIRE = {"holon": ("sync", "ckpt_put"), "flink": ("shuffle",)}
SYSTEMS = {"holon": HolonHarness, "flink": FlinkHarness}
# dissemination topologies for the critical-path comparison: the oracle,
# both sparse structured overlays, and the randomized partial view
TOPOLOGIES = ("all", "ring:2", "hypercube", "partial:2")


def _cfg(quick: bool) -> SimConfig:
    return SimConfig(
        num_batches=120 if quick else 240,
        window_len=500,
        num_slots=64,
        sync_interval_ms=50.0,
        ckpt_interval_ms=500.0,
    )


def _hist_fields(h) -> str:
    return f"avg_ms={h.avg:.2f};p50_ms={h.percentile(50):.2f};" \
           f"p99_ms={h.percentile(99):.2f};n={h.count}"


def main(quick: bool = False):
    cfg = _cfg(quick)
    q = make_q7(cfg.num_partitions, window_len=cfg.window_len, num_slots=cfg.num_slots)
    horizon = cfg.horizon_ms + 20_000.0
    t_fail = horizon * 0.3
    scen = FailureScenario.concurrent(t=t_fail)
    cfg_obs = dataclasses.replace(cfg, obs=True)

    harnesses = {}
    repeats = 2 if quick else 5
    for system, harness_cls in SYSTEMS.items():
        # warmup run first so the off/on comparison isn't skewed by JIT
        # compilation of the query dataplane (cached by function identity);
        # time only .run() — construction (log generation) is shared cost.
        # CPU JAX dispatch noise between identical runs (±10%) dwarfs the
        # telemetry delta, so: run off/on back-to-back PAIRS (adjacent runs
        # share thermal/cache state), take each pair's on/off ratio, and
        # report the median ratio — robust to the slow drift and outlier
        # stalls that make ratio-of-mins swing run to run.
        harness_cls(cfg, q).run(scen, horizon_ms=horizon)
        pairs, best_on = [], None
        for _ in range(repeats):
            ts = {}
            for label, c in (("off", cfg), ("on", cfg_obs)):
                h = harness_cls(c, q)
                with timer() as tm:
                    h.run(scen, horizon_ms=horizon)
                ts[label] = tm.dt
                if label == "on" and (best_on is None or tm.dt < best_on[0]):
                    best_on = (tm.dt, h)
            pairs.append((ts["off"], ts["on"]))
        t_off = min(p[0] for p in pairs)
        t_on = best_on[0]
        h = harnesses[system] = best_on[1]
        ratios = sorted(on / max(off, 1e-9) for off, on in pairs)
        overhead = (ratios[len(ratios) // 2] - 1.0) * 100.0
        emit(
            f"obs/overhead/{system}", t_on * 1e6,
            f"off_ms={t_off * 1e3:.0f};on_ms={t_on * 1e3:.0f};"
            f"overhead_pct={overhead:.1f};repeats={repeats};"
            f"trace_records={h.obs.buf.total}",
        )

    # ---- per-phase latency breakdown ---------------------------------------
    for system, h in harnesses.items():
        reg = h.obs.registry
        for phase in PHASES:
            hist = reg.histograms("phase_ms").get(f"phase_ms{{phase={phase}}}")
            if hist is not None and hist.count:
                emit(f"obs/phase/{phase}/{system}", 0.0, _hist_fields(hist))
        for cls in WIRE[system]:
            hist = reg.histograms("net_delivery_ms").get(
                f"net_delivery_ms{{cls={cls}}}"
            )
            if hist is not None and hist.count:
                emit(f"obs/phase/{cls}_wire/{system}", 0.0, _hist_fields(hist))

    # ---- trace-derived recovery timelines (crash + partition) --------------
    members = cfg.initial_membership
    groups = (members[: len(members) // 2], members[len(members) // 2:])
    from repro.runtime import Scenario

    part_scen = (
        Scenario("partition").partition(t_fail, *groups).heal(t_fail + 6000.0)
    )
    for scen_name, scenario in (("concurrent_crash", scen), ("partition", part_scen)):
        for system, harness_cls in SYSTEMS.items():
            h = harnesses[system] if scenario is scen else harness_cls(cfg_obs, q)
            if scenario is not scen:
                h.run(scenario, horizon_ms=horizon)
            rep = audit_harness(h)
            if not rep.ok:
                raise AssertionError(
                    f"auditor failed on obs/{scen_name}/{system}:\n{rep}"
                )
            ttr = rep.metrics.get("time_to_recover_ms", {})
            down = rep.metrics.get("flink_downtime_ms", [])
            fields = [
                "audit=ok",
                f"settle_ms={rep.metrics.get('time_to_settle_ms', 0.0):.0f}",
            ]
            if ttr:
                worst = max(ttr.values())
                fields.append(f"ttr_max_ms={worst:.0f}")
                fields.append(
                    "ttr_ms=" + ",".join(f"{n}:{t:.0f}" for n, t in ttr.items())
                )
            if down:
                fields.append(
                    "downtime_ms=" + ",".join(
                        "inf" if d == float("inf") else f"{d:.0f}" for d in down
                    )
                )
            emit(f"obs/recovery/{scen_name}/{system}", 0.0, ";".join(fields))

    # ---- critical-path phase attribution per topology ----------------------
    # failure-free but lossy/jittered fabric: the per-topology comparison is
    # about dissemination latency (sync_wait/loss_stall/wire), not recovery
    cp_cfg = dataclasses.replace(
        cfg_obs, net_loss=0.05, net_jitter="uniform", net_jitter_ms=3.0
    )
    for topo in TOPOLOGIES:
        h = HolonHarness(dataclasses.replace(cp_cfg, topology=topo), q)
        h.run(None, horizon_ms=horizon)
        _emit_critpath(f"obs/critpath/{topo.partition(':')[0]}/holon", h)
    hf = FlinkHarness(cp_cfg, q)
    hf.run(None, horizon_ms=horizon)
    _emit_critpath("obs/critpath/tree/flink", hf)

    # ---- online monitor: alerts + overhead over telemetry ------------------
    cfg_mon = dataclasses.replace(cfg_obs, obs_monitor=True)
    for system, harness_cls in SYSTEMS.items():
        # the monitor's cost is measured *directly*: swap the subscribed
        # feed for a wrapper that accumulates wall time spent inside it,
        # then report that against the rest of the run.  A/B wall-clock
        # pairs of whole runs carry ~10x the <5% budget in run-to-run
        # scheduler noise, so they can't resolve the quantity gated here.
        # (The wrapper itself bills its two clock reads per record to the
        # monitor — the estimate errs conservative.)
        best = None
        for _ in range(repeats):
            h = harness_cls(cfg_mon, q)
            spent = [0.0]
            inner = h.monitor.feed

            def timed_feed(ev, _inner=inner, _spent=spent):
                t0 = perf_counter()
                _inner(ev)
                _spent[0] += perf_counter() - t0

            h.obs.unsubscribe(inner)
            h.obs.subscribe(timed_feed)
            with timer() as tm:
                h.run(scen, horizon_ms=horizon)
            overhead = spent[0] / max(tm.dt - spent[0], 1e-9) * 100.0
            if best is None or overhead < best[0]:
                best = (overhead, tm.dt, h)
        overhead, t_mon, h = best
        mon = h.monitor
        viol = mon.violations()
        if viol:
            raise AssertionError(
                f"online monitor flagged obs/monitor/{system}: "
                + "; ".join(str(a) for a in viol[:5])
            )
        warns = Counter(a.id for a in mon.alerts if a.severity == "warn")
        warn_str = ",".join(f"{k}:{v}" for k, v in sorted(warns.items())) or "none"
        emit(
            f"obs/monitor/{system}", t_mon * 1e6,
            f"violations=0;warnings={warn_str};fed={mon.fed};"
            f"overhead_pct={overhead:.1f};repeats={repeats}",
        )

    return harnesses


def _emit_critpath(row: str, h) -> None:
    s = analyze_harness(h).summary()
    fields = [f"n={s['n']}"]
    if s["n"]:
        fields += [
            f"hops_p50={s['hops']['p50']:.1f}",
            f"hops_p99={s['hops']['p99']:.1f}",
            f"path_p50_ms={s['path_ms']['p50']:.1f}",
            f"path_p99_ms={s['path_ms']['p99']:.1f}",
        ]
        fields += [f"{ph}_ms={s['phase_ms'][ph]:.2f}" for ph in CP_PHASES]
    emit(row, 0.0, ";".join(fields))


if __name__ == "__main__":
    main()
