"""Paper Fig. 9: average Q7 latency vs cluster size at fixed per-node rate.

Input volume scales with the cluster (10k events/s/partition), mirroring the
paper's single-server emulation of 10..100 nodes.  The CPU container caps the
simulated sizes at {5, 10, 20, 40} nodes (2 partitions/node).
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, timer
from repro.runtime import SimConfig, run_flink, run_holon
from repro.streaming import make_q7

SIZES = (5, 10, 20, 40)


def main(quick: bool = False):
    sizes = SIZES[:3] if quick else SIZES
    for n in sizes:
        cfg = SimConfig(
            num_nodes=n,
            num_partitions=2 * n,
            num_batches=120 if quick else 200,
        )
        q = make_q7(cfg.num_partitions, window_len=cfg.window_len, num_slots=cfg.num_slots)
        with timer() as tm:
            ch = run_holon(cfg, q)
        sh = ch.latency_stats()
        cf = run_flink(cfg, q)
        sf = cf.latency_stats()
        emit(
            f"fig9_scalability/nodes_{n}",
            tm.dt * 1e6,
            f"holon_avg_ms={sh['avg']:.0f};flink_avg_ms={sf['avg']:.0f};"
            f"ratio={sf['avg']/max(sh['avg'],1e-9):.2f}",
        )


if __name__ == "__main__":
    main()
