"""Sub-quadratic gossip: sync traffic and latency vs cluster size per topology.

Replaces the old Fig. 9 latency-vs-size sweep with the PR-7 scaling study
(docs/protocol.md §5): the same Q7 workload is run at N in {4, 16, 64, 256}
nodes under each dissemination topology, and the per-round sync bytes/msgs
come straight from the fabric's per-class meters (docs/protocol.md §4).

Three claims are checked, row by row:

* **sub-quadratic traffic** — the log-log fitted exponent of sync bytes per
  round vs N is ~2 for the all-to-all oracle and < 1.5 for every sparse
  topology (ring / hypercube / partial view);
* **flat latency** — sparse dissemination costs propagation hops, not
  correctness or timeliness: p50 emission latency stays within a small
  constant factor of the smallest cluster's;
* **oracle identity** — the emitted window values are byte-identical to the
  all-to-all run at every size (CRDT joins are order/route-insensitive).

This is a **strong-scaling** sweep: the workload (64 partitions, fixed
event rate) is held constant while the cluster grows, so per-message delta
size stays put and the exponent isolates the dissemination schedule itself
(with ``num_partitions = N`` both message count *and* message size grow,
and every topology looks super-quadratic).  Sparse schedules also run
diameter-proportionally more frequent rounds — a sparse round costs
O(fanout x N) bytes instead of O(N^2), so the saved budget buys down the
multi-hop propagation delay and p50 stays flat; bytes *per round* (the
exponent's input) is interval-independent, and bytes/s is emitted alongside
so the frequency trade is visible.

The event log is generated once per size and shared across topologies, so
runs differ only in the dissemination schedule.  A degenerate p50 of 0 is
reported as ``degenerate`` instead of being masked by an epsilon denominator
(the old ``ratio=sf/max(sh,1e-9)`` bug hid exactly that failure mode).
"""
from __future__ import annotations

import hashlib
import math

import numpy as np

from benchmarks.common import emit, timer
from repro.runtime import HolonHarness, SimConfig
from repro.streaming import make_q7

SIZES = (4, 16, 64, 256)
TOPOS = ("all", "ring:2", "hypercube", "partial:3")


def _cfg(n: int, topo: str) -> SimConfig:
    # past ~16 nodes a sparse topology needs O(log N) beacon rounds to flood
    # liveness, so the failure-detection timeout scales with the diameter;
    # kept identical across topologies at each size so runs are comparable
    hb_timeout = 1000.0 if n <= 16 else 250.0 * (4 + 2 * math.log2(n))
    diameter = max(1, math.ceil(math.log2(n)))
    return SimConfig(
        num_nodes=n,
        num_partitions=64,  # fixed workload — see module docstring
        # the 256-node oracle run is O(N^2) simulated messages per round;
        # a shorter horizon keeps it tractable without moving the per-round
        # averages (identical across topologies at each size)
        num_batches=32 if n <= 64 else 8,
        events_per_batch=256,
        rate_per_partition=2000.0,
        window_len=500,
        num_slots=64,
        # sparse rounds are cheap, so run them diameter-proportionally more
        # often: hops x interval ~ 200ms at every size (module docstring)
        sync_interval_ms=100.0 if topo == "all" else max(25.0, 200.0 / diameter),
        hb_timeout_ms=hb_timeout,
        topology=topo,
    )


def _values_digest(consumer) -> str:
    dig = hashlib.sha256()
    for key in sorted(consumer.records):
        r = consumer.records[key]
        dig.update(repr(key).encode())
        if r.value is not None:
            dig.update(np.ascontiguousarray(np.asarray(r.value)).tobytes())
    return dig.hexdigest()


def _fit_exponent(sizes, per_round) -> float:
    xs = np.log(np.asarray(sizes, np.float64))
    ys = np.log(np.maximum(np.asarray(per_round, np.float64), 1.0))
    return float(np.polyfit(xs, ys, 1)[0])


def main(quick: bool = False):
    sizes = tuple(n for n in SIZES if n <= 64) if quick else SIZES
    series: dict[str, dict[int, float]] = {t: {} for t in TOPOS}
    p50s: dict[str, dict[int, float]] = {t: {} for t in TOPOS}
    for n in sizes:
        oracle_digest = None
        shared_log = None
        for topo in TOPOS:
            cfg = _cfg(n, topo)
            q = make_q7(
                cfg.num_partitions, window_len=cfg.window_len, num_slots=cfg.num_slots
            )
            with timer() as tm:
                h = HolonHarness(cfg, q, log=shared_log)
                h.run()
            if shared_log is None:
                shared_log = h.log  # same workload for every topology
            # normalize by simulated time actually run (horizon + drain
            # tail), not the nominal horizon — the sync loop keeps gossiping
            # through the tail, which would otherwise inflate short runs
            rounds = max(h.sim.now / cfg.sync_interval_ms, 1.0)
            bytes_rt = h.net.bytes_of("sync") / rounds
            msgs_rt = h.net.msgs_of("sync") / rounds
            bytes_s = bytes_rt / (cfg.sync_interval_ms / 1000.0)
            series[topo][n] = bytes_rt
            st = h.consumer.latency_stats()
            p50s[topo][n] = st["p50"]
            dig = _values_digest(h.consumer)
            if topo == "all":
                oracle_digest = dig
            emit(
                f"scalability/{topo}/n{n}",
                tm.dt * 1e6,
                f"sync_bytes_per_round={bytes_rt:.0f};"
                f"sync_msgs_per_round={msgs_rt:.1f};"
                f"sync_bytes_per_s={bytes_s:.0f};"
                f"p50_ms={st['p50']:.1f};n={st['n']};"
                f"match_oracle={dig == oracle_digest}",
            )
    for topo in TOPOS:
        ns = sorted(series[topo])
        if len(ns) < 2:
            continue
        exp = _fit_exponent(ns, [series[topo][n] for n in ns])
        ps = [p50s[topo][n] for n in ns]
        if min(ps) <= 0.0:
            spread = "degenerate"  # a 0 p50 means no real emissions — report
        else:
            spread = f"{max(ps) / min(ps):.2f}"
        emit(
            f"scalability/exponent/{topo}",
            0.0,
            f"exponent={exp:.2f};p50_spread={spread};sizes={'-'.join(map(str, ns))}",
        )


if __name__ == "__main__":
    main()
