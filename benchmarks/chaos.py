"""Chaos fabric benchmark: Holon vs the centralized baseline on an
*imperfect* network (runtime/net.py, docs/protocol.md §4).

Three families of rows (section ``chaos`` in BENCH_pr5.json):

* **loss sweep** — gossip/shuffle message loss ∈ {0, 1%, 10%}.  CRDT gossip
  degrades gracefully (a lost delta is subsumed by the next round's
  delta-since-unmoved-baseline, so values stay byte-identical to the
  lossless oracle and only latency moves); the baseline's TCP-like shuffle
  pays one retransmit timeout per lost transmission per tree hop.
* **partition-and-heal** — a 2-way split longer than the centralized
  detector's timeout: Holon's sides keep emitting (split-brain work
  stealing is safe — folds are idempotent under lattice merge, duplicates
  dedup) while the baseline goes down globally and replays after heal.
* **jittered links** — lognormal per-link latency jitter; gossip absorbs
  reordering (joins commute), the aggregation tree's slowest path grows.

Every Holon row cross-checks its deduplicated window values against the
lossless oracle (``values=identical``) — convergence despite loss is the
paper's claim, so the benchmark carries the evidence next to the numbers.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import emit, export_traces, latency_fields, timer
from repro.runtime import Scenario, SimConfig, run_flink, run_holon
from repro.streaming import make_q7


def chaos_config(quick: bool = False) -> SimConfig:
    return SimConfig(
        num_batches=120 if quick else 240,
        window_len=500,
        num_slots=64,
        sync_interval_ms=50.0,
        ckpt_interval_ms=300.0,
    )


def values_vs(consumer, oracle) -> str:
    """'identical' iff every oracle window is present with byte-equal value."""
    got = {k: np.asarray(r.value) for k, r in consumer.records.items()}
    ref = {k: np.asarray(r.value) for k, r in oracle.records.items()}
    missing = len(set(ref) - set(got))
    mismatch = sum(
        1 for k in ref if k in got and not np.array_equal(got[k], ref[k])
    )
    if missing == 0 and mismatch == 0:
        return "identical"
    return f"missing{missing}_mismatch{mismatch}"


def _row(c, oracle=None, base_avg=None) -> str:
    s = c.latency_stats()
    ev = sum(n for _, n in c.events_consumed)
    t_end = max((t for t, _ in c.events_consumed), default=1.0)
    drops = sum(st["dropped"] for st in c.net_stats.values())
    retries = sum(st["retries"] for st in c.net_stats.values())
    wire_mb = sum(st["bytes"] for st in c.net_stats.values()) / 1e6
    parts = [
        latency_fields(s),
        f"tput_ev_s={ev / (t_end / 1e3):.0f}", f"wire_mb={wire_mb:.2f}",
        f"dropped={drops}", f"retries={retries}",
    ]
    if base_avg:
        parts.append(f"degradation_x={s['avg'] / base_avg:.2f}")
    if oracle is not None:
        parts.append(f"values={values_vs(c, oracle)}")
    return ";".join(parts)


def main(quick: bool = False, trace_out: str | None = None):
    cfg = chaos_config(quick)
    q = make_q7(cfg.num_partitions, window_len=cfg.window_len, num_slots=cfg.num_slots)
    horizon = cfg.horizon_ms + 30_000.0

    # ---- gossip/shuffle loss sweep ----------------------------------------
    base = {}
    for pct in (0, 1, 10):
        cfgl = dataclasses.replace(cfg, net_loss=pct / 100.0)
        for system, runner in (("holon", run_holon), ("flink", run_flink)):
            with timer() as tm:
                c = runner(cfgl, q, horizon_ms=horizon)
            if pct == 0:
                base[system] = c
            oracle = base["holon"] if system == "holon" else None
            emit(
                f"chaos/loss{pct}/{system}", tm.dt * 1e6,
                _row(c, oracle=oracle, base_avg=base[system].latency_stats()["avg"]),
            )

    # ---- 2-way partition, heal after detector-visible duration -------------
    members = cfg.initial_membership
    t0 = 4000.0 if quick else 8000.0
    t1 = t0 + (4000.0 if quick else 8000.0)
    groups = (members[: len(members) // 2], members[len(members) // 2:])
    scen = Scenario("partition").partition(t0, *groups).heal(t1)
    for system, runner in (("holon", run_holon), ("flink", run_flink)):
        with timer() as tm:
            c = runner(cfg, q, scen, horizon_ms=horizon)
        oracle = base["holon"] if system == "holon" else None
        emit(
            f"chaos/partition_heal/{system}", tm.dt * 1e6,
            _row(c, oracle=oracle, base_avg=base[system].latency_stats()["avg"]),
        )
    if trace_out:
        # export obs-on traces of the partition-and-heal run (the scenario
        # exercising the widest span taxonomy) without touching the rows
        export_traces(cfg, q, scen, horizon, f"{trace_out}/chaos_partition")

    # ---- lognormal link jitter ---------------------------------------------
    cfgj = dataclasses.replace(cfg, net_jitter="lognormal", net_jitter_ms=20.0)
    for system, runner in (("holon", run_holon), ("flink", run_flink)):
        with timer() as tm:
            c = runner(cfgj, q, horizon_ms=horizon)
        oracle = base["holon"] if system == "holon" else None
        emit(
            f"chaos/jitter_lognormal20/{system}", tm.dt * 1e6,
            _row(c, oracle=oracle, base_avg=base[system].latency_stats()["avg"]),
        )

    return base


if __name__ == "__main__":
    main()
