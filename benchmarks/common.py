"""Shared benchmark plumbing: CSV emission per the harness contract."""
from __future__ import annotations

import sys
import time


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
    sys.stdout.flush()


class timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0
