"""Shared benchmark plumbing: CSV emission per the harness contract, plus a
row registry so drivers (benchmarks/run.py) can also write the results as
machine-readable JSON (section -> rows) for the perf trajectory."""
from __future__ import annotations

import sys
import time

# section -> [row, ...]; populated by emit() while a section is active
ROWS: dict[str, list[dict]] = {}
_section: str | None = None


def set_section(name: str | None) -> None:
    """Route subsequent emit() rows to ``name`` (None stops recording)."""
    global _section
    _section = name
    if name is not None:
        ROWS.setdefault(name, [])


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
    sys.stdout.flush()
    if _section is not None:
        ROWS[_section].append(
            {"name": name, "us_per_call": round(us_per_call, 3), "derived": derived}
        )


class timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0
