"""Shared benchmark plumbing: CSV emission per the harness contract, plus a
row registry so drivers (benchmarks/run.py) can also write the results as
machine-readable JSON (section -> rows) for the perf trajectory.

Timing and latency summaries are delegated to repro.obs (docs/
observability.md §1): ``timer`` IS :class:`repro.obs.timing.WallTimer` — the
one sanctioned wall-clock stopwatch — and :func:`latency_fields` formats a
consumer's ``latency_stats()`` (computed by the shared
``repro.obs.registry.summary``) so every benchmark row spells avg/p99
identically.
"""
from __future__ import annotations

import sys

from repro.obs.timing import WallTimer

# the benchmark stopwatch: wall-clock domain, `.dt` seconds after the block
timer = WallTimer

# section -> [row, ...]; populated by emit() while a section is active
ROWS: dict[str, list[dict]] = {}
_section: str | None = None


def set_section(name: str | None) -> None:
    """Route subsequent emit() rows to ``name`` (None stops recording)."""
    global _section
    _section = name
    if name is not None:
        ROWS.setdefault(name, [])


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
    sys.stdout.flush()
    if _section is not None:
        ROWS[_section].append(
            {"name": name, "us_per_call": round(us_per_call, 3), "derived": derived}
        )


def latency_fields(stats: dict, sep: str = ";") -> str:
    """The canonical ``avg_ms=..;p99_ms=..;n=..`` spelling of a consumer's
    ``latency_stats()`` dict, shared by every latency-reporting section."""
    return sep.join(
        (f"avg_ms={stats['avg']:.0f}", f"p99_ms={stats['p99']:.0f}", f"n={stats['n']}")
    )


def memory_fields(state_bytes: float, peak_bytes: float | None = None,
                  sep: str = ";") -> str:
    """The canonical ``state_bytes_per_dev=..[;peak_live_bytes=..]`` spelling
    of a row's per-device memory footprint, shared by every section that
    reports one (throughput, keyed scale) — same dedup role as
    :func:`latency_fields` plays for latency summaries."""
    parts = [f"state_bytes_per_dev={state_bytes:.0f}"]
    if peak_bytes is not None:
        parts.append(f"peak_live_bytes={peak_bytes:.0f}")
    return sep.join(parts)


def export_traces(cfg, query, scenario, horizon_ms, out_prefix) -> dict:
    """Re-run ``scenario`` with telemetry on (both runtimes) and export the
    traces next to the benchmark rows: ``<prefix>_<system>.jsonl`` (full
    record stream) and ``<prefix>_<system>.trace.json`` (Chrome trace-event
    JSON — load in Perfetto / chrome://tracing, docs/observability.md §3).

    A separate obs-on run, so the benchmark rows themselves keep coming from
    the exact telemetry-off configuration they always used.  Returns
    {system: harness} for callers that want to audit the traces too.
    """
    import dataclasses
    import json
    from pathlib import Path

    from repro.runtime.flink_baseline import FlinkHarness
    from repro.runtime.harness import HolonHarness

    cfg_obs = dataclasses.replace(cfg, obs=True)
    out: dict = {}
    for system, harness_cls in (("holon", HolonHarness), ("flink", FlinkHarness)):
        h = harness_cls(cfg_obs, query)
        h.run(scenario, horizon_ms=horizon_ms)
        prefix = Path(f"{out_prefix}_{system}")
        prefix.parent.mkdir(parents=True, exist_ok=True)
        prefix.with_suffix(".jsonl").write_text(h.obs.export_jsonl())
        prefix.with_suffix(".trace.json").write_text(
            json.dumps(h.obs.export_chrome())
        )
        out[system] = h
    return out
