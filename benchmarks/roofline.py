"""§Roofline: per-(arch x shape) roofline terms from the dry-run artifacts.

Reads benchmarks/results/dryrun/*.json (written by repro.launch.dryrun) and
prints the single-pod table: compute / memory / collective seconds per step,
dominant term, and MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import emit

RESULTS = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"


def load(mesh: str = "pod_16x16") -> list[dict]:
    recs = []
    for p in sorted(RESULTS.glob(f"*_{mesh}.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def main(quick: bool = False):
    recs = load()
    if not recs:
        emit("roofline/missing", 0.0, "run `python -m repro.launch.dryrun --all` first")
        return
    for r in recs:
        t = r["roofline"]
        coll = r["collective_bytes_per_device"]
        emit(
            f"roofline/{r['arch']}/{r['cell']}",
            t[max(("compute_s", "memory_s", "collective_s"), key=lambda k: t[k])] * 1e6,
            f"compute_ms={t['compute_s']*1e3:.2f};memory_ms={t['memory_s']*1e3:.2f};"
            f"collective_ms={t['collective_s']*1e3:.2f};dominant={t['dominant']};"
            f"useful_flops_ratio={r['useful_flops_ratio'] and round(r['useful_flops_ratio'],3)};"
            f"coll_bytes={coll.get('total',0):.2e}",
        )
    # summary: worst / best useful ratio, most collective-bound
    scored = [r for r in recs if r.get("useful_flops_ratio")]
    if scored:
        worst = min(scored, key=lambda r: r["useful_flops_ratio"])
        emit(
            "roofline/summary", 0.0,
            f"cells={len(recs)};worst_useful={worst['arch']}/{worst['cell']}"
            f"={worst['useful_flops_ratio']:.3f}",
        )


if __name__ == "__main__":
    main()
