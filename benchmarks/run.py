"""Benchmark harness entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Sections:
  fig6_table2   failure recovery latency (Holon vs Flink-like)
  fig7_8        latency sensitivity under failures
  fig9          scalability with cluster size
  elasticity    4→8→4 elastic transitions vs stop-the-world rebalance
  throughput    max-throughput (sim peak) + real dataplane events/s
  roofline      per-(arch x shape) roofline terms from the dry-run
  kernels       WCRDT fold/merge/topk microbenchmarks

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only SECTION]
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args()

    from benchmarks import (
        elasticity,
        failure_recovery,
        kernels_bench,
        roofline,
        scalability,
        sensitivity,
        throughput,
    )

    sections = {
        "kernels": kernels_bench.main,
        "roofline": roofline.main,
        "throughput": throughput.main,
        "fig6_table2": failure_recovery.main,
        "fig7_8": sensitivity.main,
        "fig9": scalability.main,
        "elasticity": elasticity.main,
    }
    print("name,us_per_call,derived")
    failed = []
    for name, fn in sections.items():
        if args.only and args.only != name:
            continue
        try:
            fn(quick=args.quick)
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
            print(f"{name}/ERROR,0,{repr(e)[:120]}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
