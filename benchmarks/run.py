"""Benchmark harness entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines and writes the same rows as
machine-readable JSON (``{"sections": {section: [row, ...]}}``) to
``BENCH_pr9.json`` so the perf trajectory accumulates across PRs.  Sections:
  fig6_table2   failure recovery latency (Holon vs Flink-like)
  fig7_8        latency sensitivity under failures
  scalability   sync traffic + latency vs cluster size per gossip topology
  elasticity    4→8→4 elastic transitions vs stop-the-world rebalance
  chaos         lossy/partitioned/jittered network fabric (Holon vs Flink)
  obs           per-phase latency breakdown + trace-audited recovery
                timelines + telemetry overhead (docs/observability.md)
  throughput    max-throughput (sim peak) + real dataplane events/s
  keyed         million-key sharded-vs-dense keyed-state scaling sweep
  roofline      per-(arch x shape) roofline terms from the dry-run
  kernels       WCRDT fold/merge/topk microbenchmarks

``--trace-out DIR`` additionally exports obs-on traces (JSONL + Chrome
trace-event JSON for Perfetto) from the chaos and elasticity sections.

``--summary`` skips running anything and instead merges every committed
``BENCH_pr*.json`` into one perf-trajectory table: per row, the first and
latest recorded value and the delta across PRs.

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--only SECTION]
                                               [--json PATH] [--trace-out DIR]
       PYTHONPATH=src python -m benchmarks.run --summary
"""
import argparse
import json
import platform
import re
import sys
import traceback
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
BENCH_JSON = REPO / "BENCH_pr10.json"


def summarize(root: Path = REPO) -> list[str]:
    """The cross-PR perf trajectory: merge all ``BENCH_pr*.json`` (in PR
    order) and render one line per row name with first/last/delta of
    ``us_per_call`` plus the latest derived fields.  Returns the lines so
    tests can assert on them; ``--summary`` prints them."""
    files = sorted(
        root.glob("BENCH_pr*.json"),
        key=lambda p: int(re.search(r"pr(\d+)", p.name).group(1)),
    )
    # row name -> [(pr, us_per_call, derived), ...] in PR order
    trail: dict[str, list[tuple[int, float, str]]] = {}
    sections: dict[str, str] = {}  # row name -> section (latest wins)
    for path in files:
        pr = int(re.search(r"pr(\d+)", path.name).group(1))
        try:
            doc = json.loads(path.read_text())
        except (json.JSONDecodeError, OSError):
            continue
        for section, rows in (doc.get("sections") or {}).items():
            for row in rows:
                name = row.get("name")
                if not isinstance(name, str):
                    continue
                sections[name] = section
                trail.setdefault(name, []).append(
                    (pr, float(row.get("us_per_call") or 0.0),
                     str(row.get("derived") or ""))
                )
    lines = [f"# perf trajectory over {len(files)} benchmark files "
             f"({', '.join(p.name for p in files)})",
             "section,name,first_pr,last_pr,first_us,last_us,delta_pct,derived"]
    for name in sorted(trail, key=lambda n: (sections[n], n)):
        t = trail[name]
        (pr0, us0, _), (pr1, us1, derived) = t[0], t[-1]
        delta = ((us1 - us0) / us0 * 100.0) if us0 else 0.0
        lines.append(
            f"{sections[name]},{name},{pr0},{pr1},{us0:.3f},{us1:.3f},"
            f"{delta:+.1f}%,{derived}"
        )
    return lines


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", type=str, default=None)
    ap.add_argument("--json", type=Path, default=BENCH_JSON,
                    help="where to write the machine-readable results")
    ap.add_argument("--trace-out", type=str, default=None,
                    help="directory for obs-on trace exports (JSONL + Chrome "
                         "trace JSON) from the chaos and elasticity sections")
    ap.add_argument("--summary", action="store_true",
                    help="print the cross-PR perf trajectory from the "
                         "committed BENCH_pr*.json files and exit")
    args = ap.parse_args()
    if args.summary:
        print("\n".join(summarize()))
        return

    from benchmarks import (
        chaos,
        elasticity,
        failure_recovery,
        kernels_bench,
        keyed_scale,
        observability,
        roofline,
        scalability,
        sensitivity,
        throughput,
    )

    sections = {
        "kernels": kernels_bench.main,
        "roofline": roofline.main,
        "throughput": throughput.main,
        "keyed": keyed_scale.main,
        "fig6_table2": failure_recovery.main,
        "fig7_8": sensitivity.main,
        "scalability": scalability.main,
        "elasticity": lambda quick: elasticity.main(
            quick=quick, trace_out=args.trace_out
        ),
        "chaos": lambda quick: chaos.main(quick=quick, trace_out=args.trace_out),
        "obs": observability.main,
    }
    from benchmarks import common

    if args.only and args.only not in sections:
        ap.error(f"--only must be one of {sorted(sections)}; got {args.only!r}")
    print("name,us_per_call,derived")
    failed = []
    for name, fn in sections.items():
        if args.only and args.only != name:
            continue
        common.set_section(name)
        try:
            fn(quick=args.quick)
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
            print(f"{name}/ERROR,0,{repr(e)[:120]}")
        finally:
            common.set_section(None)
    # JSON mirror of the CSV rows — written even on partial failure, and
    # merged over any existing file so a --only/--quick run refreshes just
    # the sections it executed instead of discarding the rest.  A section
    # that errored this run keeps its last good rows (its partial rows are
    # worse than stale ones); section_meta records per-section provenance
    # so --quick and full-run rows are distinguishable after a merge.
    prev_sections, prev_failed, prev_meta = {}, [], {}
    if args.json.exists():
        try:
            prev = json.loads(args.json.read_text())
            if isinstance(prev, dict):  # wrong-shape JSON: rewrite from scratch
                def _dict(v):
                    return v if isinstance(v, dict) else {}

                prev_sections = _dict(prev.get("sections"))
                raw_failed = prev.get("failed_sections")
                if isinstance(raw_failed, list):
                    prev_failed = [s for s in raw_failed if isinstance(s, str)]
                prev_meta = _dict(prev.get("section_meta"))
        except (json.JSONDecodeError, OSError):
            pass  # unreadable file: rewrite from this run
    good = {
        name: rows for name, rows in common.ROWS.items()
        if name not in failed or name not in prev_sections
    }
    meta = {
        name: {"quick": bool(args.quick), "failed": name in failed}
        for name in good
    }
    args.json.write_text(json.dumps(
        {
            "schema": "holon-bench-v1",
            "only": args.only,
            "platform": platform.platform(),
            "failed_sections": sorted(
                (set(prev_failed) - set(common.ROWS)) | set(failed)
            ),
            "section_meta": {**prev_meta, **meta},
            "sections": {**prev_sections, **good},
        },
        indent=2,
    ) + "\n")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
