"""Kernel-layer microbenchmarks: the WCRDT fold / merge / top-k hot paths.

On this CPU host the jnp reference path runs (the Pallas kernels lower for
TPU and are validated in interpret mode); the numbers are the real dataplane
cost the simulation charges per batch.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels.ops import crdt_merge, topk_window, window_agg


def _time(fn, *args, reps=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6  # us


def main(quick: bool = False):
    rng = np.random.default_rng(0)
    B, W, C, k = 4096, 64, 8, 8
    vals = jnp.array(rng.random(B, dtype=np.float32))
    slots = jnp.array(rng.integers(0, W, B).astype(np.int32))
    keys = jnp.array(rng.integers(0, C, B).astype(np.int32))
    mask = jnp.ones(B, bool)

    for op in ("sum", "max"):
        us = _time(lambda: window_agg(vals, slots, mask, W, op=op))
        emit(f"kernels/window_agg_{op}_B{B}_W{W}", us, f"ev_per_s={B/us*1e6/1e6:.1f}M")
    us = _time(lambda: window_agg(vals, slots, mask, W, op="sum", keys=keys, C=C))
    emit(f"kernels/window_agg_keyed_B{B}_W{W}_C{C}", us, f"ev_per_s={B/us*1e6/1e6:.1f}M")

    stack = jnp.array(rng.random((16, 1 << 16), dtype=np.float32))
    us = _time(lambda: crdt_merge(stack, op="max"))
    emit("kernels/crdt_merge_R16_F65536", us, f"GBps={stack.nbytes/us*1e6/1e9:.1f}")

    sv = jnp.full((W, k), -jnp.inf, jnp.float32)
    si = jnp.zeros((W, k), jnp.uint32)
    ids = jnp.array(rng.integers(0, 1000, B).astype(np.uint32))
    us = _time(lambda: topk_window(sv, si, vals, ids, slots, mask))
    emit(f"kernels/topk_window_B{B}_W{W}_k{k}", us, f"ev_per_s={B/us*1e6/1e6:.1f}M")


if __name__ == "__main__":
    main()
