"""Elastic reconfiguration: watermark-latency during 4→8→4 node transitions.

Runs Q7 under a zipf-skewed load hot enough that 4 nodes sit near saturation,
then compares two ways of changing the cluster size mid-run:

  elastic : the Holon way (docs/protocol.md §3) — scale_out adds nodes that
            bootstrap from a live peer while everyone keeps processing;
            scale_in drains nodes with a final delta flush + handoff
            checkpoints.  No global pause anywhere.
  stw     : a stop-the-world rebalance baseline — every node is quiesced at
            the transition, state is redistributed through storage, and the
            new membership restarts ``stw_pause_ms`` later (the
            checkpoint-restore rebalance that centralized runtimes do).

Reported per run: avg/p99 latency, the max latency spike inside a window
around each transition, and the settle time back to pre-transition latency.
The elastic run's deduplicated outputs are also checked byte-identical to a
fixed-membership oracle — scale events must not violate exactly-once.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import emit, latency_fields, timer
from repro.runtime import Scenario, SimConfig, run_holon
from repro.streaming import make_q7

BASE_NODES = (0, 1, 2, 3)
NEW_NODES = (4, 5, 6, 7)
STW_PAUSE_MS = 1500.0  # quiesce + redistribute + resume for the baseline
SPIKE_WIN_MS = 5000.0  # window around a transition scanned for the spike


def _cfg(quick: bool) -> SimConfig:
    # 16 skewed partitions on 4 nodes at ~90% utilization: batch span is
    # 51.2 ms and a mean-load partition costs ~11.5 ms/batch, so 4-ish
    # partitions/node saturate a node — scale-out visibly relieves latency.
    return SimConfig(
        num_nodes=len(BASE_NODES),
        num_partitions=16,
        num_batches=120 if quick else 240,
        events_per_batch=512,
        window_len=500,
        num_slots=64,
        batch_proc_ms=20.0,
        skew=0.5,
        sync_interval_ms=50.0,
        ckpt_interval_ms=500.0,
    )


def spike_stats(consumer, t0: float, win_ms: float, base_avg: float):
    """(max latency, settle time) inside [t0, t0+win_ms): settle = time from
    the transition until window latencies return below 3x the quiet avg."""
    t, lat = consumer.latency_series()
    m = (t >= t0) & (t < t0 + win_ms)
    if not m.any():
        return 0.0, 0.0
    peak = float(lat[m].max())
    bad = m & (lat > 3.0 * max(base_avg, 1.0))
    settle = float(t[bad].max() - t0) if bad.any() else 0.0
    return peak, settle


def main(quick: bool = False, trace_out: str | None = None):
    cfg = _cfg(quick)
    q = make_q7(cfg.num_partitions, window_len=cfg.window_len, num_slots=cfg.num_slots)
    horizon = cfg.horizon_ms
    t_out, t_in = horizon * 0.33, horizon * 0.66

    scenarios = {
        "fixed4": Scenario("fixed4"),
        "elastic": Scenario("elastic")
        .scale_out(t_out, *NEW_NODES)
        .scale_in(t_in, *NEW_NODES),
        # stop-the-world: at each transition every running node crashes and
        # the post-transition membership restarts after the rebalance pause
        "stw": Scenario("stw")
        .crash(t_out, *BASE_NODES)
        .restart(t_out + STW_PAUSE_MS, *BASE_NODES)
        .scale_out(t_out + STW_PAUSE_MS, *NEW_NODES)
        .crash(t_in, *BASE_NODES, *NEW_NODES)
        .restart(t_in + STW_PAUSE_MS, *BASE_NODES)
        # decommission the crashed extra nodes so publishers stop paying
        # per-peer cost for them (docs/protocol.md §3.3)
        .scale_in(t_in + STW_PAUSE_MS, *NEW_NODES),
    }

    results = {}
    for name, scen in scenarios.items():
        with timer() as tm:
            c = run_holon(cfg, q, scen, horizon_ms=horizon + 15_000)
        results[name] = c
        s = c.latency_stats()
        base_avg = results["fixed4"].latency_stats()["avg"]
        pk_out, st_out = spike_stats(c, t_out, SPIKE_WIN_MS, base_avg)
        pk_in, st_in = spike_stats(c, t_in, SPIKE_WIN_MS, base_avg)
        emit(
            f"elasticity/{name}",
            tm.dt * 1e6,
            f"{latency_fields(s)};"
            f"out_peak_ms={pk_out:.0f};out_settle_ms={st_out:.0f};"
            f"in_peak_ms={pk_in:.0f};in_settle_ms={st_in:.0f}",
        )
    if trace_out:
        # obs-on export of the elastic 4→8→4 run (join/drain/handoff spans);
        # the Flink half of export_traces is skipped — the baseline is
        # fixed-membership and rejects scale events
        import json
        from pathlib import Path

        from repro.runtime.harness import HolonHarness

        h = HolonHarness(dataclasses.replace(cfg, obs=True), q)
        h.run(scenarios["elastic"], horizon_ms=horizon + 15_000)
        prefix = Path(f"{trace_out}/elasticity_holon")
        prefix.parent.mkdir(parents=True, exist_ok=True)
        prefix.with_suffix(".jsonl").write_text(h.obs.export_jsonl())
        prefix.with_suffix(".trace.json").write_text(
            json.dumps(h.obs.export_chrome())
        )

    # exactly-once across elasticity: the elastic run's deduplicated outputs
    # must be byte-identical to the fixed-membership oracle
    oracle = {k: np.asarray(r.value) for k, r in results["fixed4"].records.items()}
    got = {k: np.asarray(r.value) for k, r in results["elastic"].records.items()}
    missing = set(oracle) - set(got)
    extra = set(got) - set(oracle)  # spurious windows the oracle never emitted
    mismatched = sum(
        0 if np.array_equal(got[k], oracle[k]) else 1 for k in oracle if k in got
    )
    ok = not missing and not extra and mismatched == 0
    emit(
        "elasticity/exactly_once",
        0.0,
        f"ok={ok};oracle_windows={len(oracle)};missing={len(missing)};"
        f"extra={len(extra)};mismatched={mismatched}",
    )
    if not ok:
        raise AssertionError(
            f"elastic run violated exactly-once: missing={len(missing)} "
            f"extra={len(extra)} mismatched={mismatched}"
        )
    return results


if __name__ == "__main__":
    main()
